//! Property tests (DESIGN.md §7): random training-shaped DAGs replayed
//! under random budgets and every heuristic/policy must preserve the DTR
//! invariants — budget safety, lock hygiene, output condition, determinism,
//! and accounting consistency. Uses the in-tree miniprop harness (proptest
//! is not in the offline crate cache).

use dtr::dtr::{Config, DeallocPolicy, Heuristic};
use dtr::exec::{Engine, Optimizer};
use dtr::graphs::tape::{R, Tape};
use dtr::runtime::{InterpExecutor, ModelConfig, NullExecutor};
use dtr::sim::log::Log;
use dtr::sim::replay::{baseline, simulate};
use dtr::util::miniprop::check;
use dtr::util::rng::Rng;

/// Random layered training DAG via the Tape (fan-out, weights, releases).
fn random_model(rng: &mut Rng, size: usize) -> Log {
    let mut t = Tape::new("prop");
    let x = t.data("x", 64 + rng.below(512));
    let mut frontier: Vec<R> = vec![x];
    let mut nodes = 0usize;
    while nodes < size {
        let k = 1 + rng.index(2.min(frontier.len()));
        let mut inputs: Vec<R> = (0..k).map(|_| *rng.choose(&frontier)).collect();
        if rng.chance(0.5) {
            let w = t.weight(&format!("w{nodes}"), 16 + rng.below(128));
            inputs.push(w);
        }
        let out = t.op(
            &format!("op{nodes}"),
            1 + rng.below(50),
            &inputs,
            32 + rng.below(1024),
        );
        frontier.push(out);
        if frontier.len() > 4 {
            frontier.remove(0);
        }
        nodes += 1;
    }
    let last = *frontier.last().unwrap();
    let loss = t.op("loss", 1, &[last], 8);
    t.finish(loss)
}

#[test]
fn prop_budget_safety_and_invariants_all_heuristics() {
    check("budget_safety", 60, 5, 40, |rng, size| {
        let log = random_model(rng, size);
        let b = baseline(&log);
        let h = *rng.choose(&Heuristic::fig2_set());
        let ratio = 0.3 + rng.f64() * 0.7;
        let budget = b.budget_at(ratio);
        let out = simulate(&log, Config { budget, heuristic: h, ..Config::default() });
        if let Some(fail) = &out.failed {
            // OOM is legal at low ratios; anything else is a bug.
            if fail.contains("out of memory") {
                return Ok(());
            }
            return Err(format!("{} at ratio {ratio:.2}: {fail}", h.name()));
        }
        if out.stats.peak_memory > budget {
            return Err(format!(
                "{}: peak {} exceeded budget {budget}",
                h.name(),
                out.stats.peak_memory
            ));
        }
        if out.stats.total_compute() < b.total_compute {
            return Err("computed less than the baseline?!".into());
        }
        Ok(())
    });
}

#[test]
fn prop_all_policies_sound() {
    check("policy_soundness", 45, 5, 30, |rng, size| {
        let log = random_model(rng, size);
        let b = baseline(&log);
        let policy = *rng.choose(&DeallocPolicy::all());
        let budget = b.budget_at(0.5 + rng.f64() * 0.5);
        let out = simulate(
            &log,
            Config { budget, heuristic: Heuristic::dtr(), policy, ..Config::default() },
        );
        if let Some(fail) = &out.failed {
            if fail.contains("out of memory") {
                return Ok(());
            }
            return Err(format!("{}: {fail}", policy.name()));
        }
        Ok(())
    });
}

#[test]
fn prop_determinism() {
    check("determinism", 25, 5, 30, |rng, size| {
        let log = random_model(rng, size);
        let b = baseline(&log);
        let cfg = Config {
            budget: b.budget_at(0.45),
            heuristic: Heuristic::dtr_eq(),
            ..Config::default()
        };
        let x = simulate(&log, cfg.clone());
        let y = simulate(&log, cfg);
        if x.stats.total_compute() != y.stats.total_compute()
            || x.stats.evict_count != y.stats.evict_count
        {
            return Err("two identical runs diverged".into());
        }
        Ok(())
    });
}

#[test]
fn prop_unbudgeted_equals_baseline_compute() {
    check("unbudgeted_baseline", 30, 5, 40, |rng, size| {
        let log = random_model(rng, size);
        let b = baseline(&log);
        let out = simulate(&log, Config::default());
        if !out.ok() {
            return Err(format!("unbudgeted failed: {:?}", out.failed));
        }
        if out.stats.total_compute() != b.total_compute {
            return Err("unbudgeted run recomputed something".into());
        }
        if out.stats.remat_count != 0 {
            return Err("unbudgeted run rematerialized".into());
        }
        Ok(())
    });
}

#[test]
fn prop_jsonl_roundtrip_preserves_simulation() {
    check("jsonl_roundtrip", 25, 5, 25, |rng, size| {
        let log = random_model(rng, size);
        let back = Log::from_jsonl(&log.to_jsonl()).map_err(|e| e.to_string())?;
        let b = baseline(&log);
        let cfg = Config { budget: b.budget_at(0.5), ..Config::default() };
        let x = simulate(&log, cfg.clone());
        let y = simulate(&back, cfg);
        if x.ok() != y.ok() {
            return Err("roundtrip changed feasibility".into());
        }
        if x.ok() && x.stats.total_compute() != y.stats.total_compute() {
            return Err("roundtrip changed compute".into());
        }
        Ok(())
    });
}

/// Backend-equivalence: replaying the same training-step op log through the
/// accounting-only NullExecutor and the real interpreter executor must
/// produce identical DTR `Stats` — eviction/rematerialization decisions
/// depend only on sizes, costs, and the heuristic, never on buffer values
/// or on which backend computes them.
#[test]
fn prop_backend_equivalence_null_vs_interp() {
    let model = ModelConfig {
        vocab: 32,
        d_model: 16,
        n_heads: 2,
        d_ff: 32,
        seq: 8,
        batch: 2,
        n_layers: 2,
    };
    check("backend_equivalence", 10, 1, 100, |rng, _size| {
        let h = *rng.choose(&Heuristic::fig2_set());
        let pct = 55 + rng.below(40); // 55..95% of the non-pinned headroom
        let opt = if rng.chance(0.5) { Optimizer::Adam } else { Optimizer::Sgd };

        let mk = |null: bool| -> Engine {
            let exec: Box<dyn dtr::runtime::Executor> = if null {
                Box::new(NullExecutor::new(model).unwrap())
            } else {
                Box::new(InterpExecutor::new(model).unwrap())
            };
            Engine::new(exec, Config::default(), opt).unwrap()
        };

        let mut interp = mk(false);
        let mut null = mk(true);
        let peak_i = interp.measure_peak().map_err(|e| e.to_string())?;
        let peak_n = null.measure_peak().map_err(|e| e.to_string())?;
        if peak_i != peak_n {
            return Err(format!("unbudgeted peaks differ: interp {peak_i} vs null {peak_n}"));
        }
        let budget = interp.budgets_from_peak(peak_i, &[pct])[0];
        let cfg = Config { budget, heuristic: h, ..Config::default() };
        interp.dtr_cfg = cfg.clone();
        null.dtr_cfg = cfg;

        for step in 0..2 {
            let a = interp.train_step();
            let b = null.train_step();
            match (a, b) {
                // OOM is legal at tight budgets, but both backends must
                // agree on feasibility.
                (Err(_), Err(_)) => return Ok(()),
                (Ok(_), Err(e)) => {
                    return Err(format!("{}: null OOMed but interp ran: {e:#}", h.name()))
                }
                (Err(e), Ok(_)) => {
                    return Err(format!("{}: interp OOMed but null ran: {e:#}", h.name()))
                }
                (Ok(ra), Ok(rb)) => {
                    let key = |s: &dtr::dtr::Stats| {
                        (
                            s.clock,
                            s.base_compute,
                            s.remat_compute,
                            s.remat_count,
                            s.evict_count,
                            s.peak_memory,
                            s.memory,
                        )
                    };
                    if key(&ra.stats) != key(&rb.stats) {
                        return Err(format!(
                            "{} step {step}: stats diverged\n interp: {:?}\n null:   {:?}",
                            h.name(),
                            ra.stats,
                            rb.stats
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_lower_budget_never_lowers_compute() {
    check("budget_monotone_compute", 30, 8, 30, |rng, size| {
        let log = random_model(rng, size);
        let b = baseline(&log);
        let tight = simulate(
            &log,
            Config { budget: b.budget_at(0.4), heuristic: Heuristic::dtr_eq(), ..Config::default() },
        );
        let loose = simulate(
            &log,
            Config { budget: b.budget_at(0.9), heuristic: Heuristic::dtr_eq(), ..Config::default() },
        );
        if !tight.ok() || !loose.ok() {
            return Ok(()); // OOM cases covered elsewhere
        }
        if tight.stats.total_compute() < loose.stats.total_compute() {
            return Err(format!(
                "tighter budget computed less: {} < {}",
                tight.stats.total_compute(),
                loose.stats.total_compute()
            ));
        }
        Ok(())
    });
}
