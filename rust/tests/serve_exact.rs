//! N=1-tenant serving is **decision-exact** against a plain single
//! `Session` under the same byte budget: identical victim sequences and
//! `Stats::same_decisions`, for both arbitration policies. This is the
//! serve-layer analogue of the policy-index equivalence property (PR 3):
//! the arbiter's reclaim loop must degenerate to exactly the fixed-budget
//! `free_for` loop when there is nobody to reclaim from.
//!
//! The fleet-tournament analogue
//! (`shared_tournament_is_decision_exact_vs_peek_scan`) pins the shared
//! cross-shard index (`GlobalIndexKind::Shared`) to the retained
//! peek-scan loop on a multi-shard round-robin fleet the same way.

use dtr::api::{Session, Tensor};
use dtr::dtr::{Config, Heuristic, NullBackend, Stats};
use dtr::exec::dynamic::{headroom_budget, LstmTrainer};
use dtr::runtime::RnnConfig;
use dtr::serve::{ArbiterPolicy, GlobalIndexKind, ServePool};
use dtr::util::rng::Rng;

/// Drive a deterministic randomized tape (calls, releases, touches) through
/// any accounting session; the op stream depends only on `seed`.
fn drive(s: &Session<NullBackend>, seed: u64, ops: usize) -> Stats {
    let mut rng = Rng::new(seed);
    let mut live: Vec<Tensor> = vec![s.constant_sized(8)];
    for i in 0..ops {
        let src = rng.index(live.len());
        let out_bytes = 1 + rng.below(16);
        let cost = 1 + rng.below(5);
        let t = s
            .call_sized(&format!("op{i}"), cost, &[&live[src]], &[out_bytes])
            .expect("tape op under budget")
            .remove(0);
        live.push(t);
        if live.len() > 24 {
            // Deterministic release (never the pinned constant).
            let k = 1 + rng.index(live.len() - 2);
            drop(live.remove(k));
        }
        if i % 17 == 0 && live.len() > 3 {
            let k = 1 + rng.index(live.len() - 1);
            s.touch(&live[k]).expect("touch remat under budget");
        }
    }
    s.check_invariants().unwrap();
    s.stats()
}

/// Unbudgeted peak of the tape (for sizing the budget rungs).
fn tape_peak(seed: u64, ops: usize) -> u64 {
    let s = Session::accounting(Config::default());
    drive(&s, seed, ops).peak_memory
}

#[test]
fn single_tenant_accounting_tape_is_decision_exact() {
    const SEED: u64 = 0xACC0;
    const OPS: usize = 400;
    let peak = tape_peak(SEED, OPS);
    // Loose enough that the single-op working set always fits, tight
    // enough to force a steady eviction stream.
    let budget = 8 + (peak - 8) * 45 / 100;
    for h in [Heuristic::dtr_eq(), Heuristic::dtr(), Heuristic::lru(), Heuristic::size()] {
        let plain = {
            let s = Session::accounting(Config {
                budget,
                heuristic: h,
                trace_victims: true,
                ..Config::default()
            });
            drive(&s, SEED, OPS)
        };
        assert!(plain.evict_count > 0, "{}: budget never binds", h.name());
        for policy in ArbiterPolicy::all() {
            let pool = ServePool::new(budget, policy, 1);
            let served = {
                let s = Session::accounting(Config {
                    heuristic: h,
                    trace_victims: true,
                    gate: Some(pool.lease()),
                    ..Config::default()
                });
                drive(&s, SEED, OPS)
            };
            assert!(
                plain.same_decisions(&served),
                "{} under {} diverged from the plain session:\nplain  {:?}\nserved {:?}",
                h.name(),
                policy.name(),
                plain,
                served
            );
            pool.check_invariants().unwrap();
        }
    }
}

/// Round-robin a deterministic tape on each of `shards` gated sessions;
/// the per-shard op streams depend only on the shard index. Returns each
/// shard's final stats (victim traces included).
fn drive_fleet(pool: &ServePool, shards: usize, ops: usize, h: Heuristic) -> Vec<Stats> {
    let sessions: Vec<Session<NullBackend>> = (0..shards)
        .map(|_| {
            Session::accounting(Config {
                heuristic: h,
                trace_victims: true,
                // Upgrade the auto index immediately so the differential
                // tournament (the publishing index) is what runs.
                auto_crossover: 0,
                gate: Some(pool.lease()),
                ..Config::default()
            })
        })
        .collect();
    let mut lives: Vec<Vec<Tensor>> =
        sessions.iter().map(|s| vec![s.constant_sized(8)]).collect();
    let mut rngs: Vec<Rng> = (0..shards).map(|i| Rng::new(0xF1EE7 + i as u64)).collect();
    for i in 0..ops {
        for sh in 0..shards {
            let (s, live, rng) = (&sessions[sh], &mut lives[sh], &mut rngs[sh]);
            let src = rng.index(live.len());
            let out_bytes = 1 + rng.below(16);
            let cost = 1 + rng.below(5);
            let t = s
                .call_sized(&format!("s{sh}op{i}"), cost, &[&live[src]], &[out_bytes])
                .expect("fleet tape op under budget")
                .remove(0);
            live.push(t);
            if live.len() > 16 {
                let k = 1 + rng.index(live.len() - 2);
                drop(live.remove(k));
            }
            if i % 17 == 0 && live.len() > 3 {
                let k = 1 + rng.index(live.len() - 1);
                s.touch(&live[k]).expect("fleet touch remat under budget");
            }
        }
    }
    sessions
        .iter()
        .map(|s| {
            s.check_invariants().unwrap();
            s.stats()
        })
        .collect()
}

/// The tentpole exactness pin: `GlobalIndexKind::Shared` (one fleet
/// tournament fed by published per-shard minima) must pick the *same
/// victims in the same order* as `GlobalIndexKind::Scan` (the retained
/// peek-every-shard loop) on a deterministic round-robin fleet — per
/// shard, `Stats::same_decisions` across the two pools. Staleness-bearing
/// heuristics exercise the published fast path (scores are republished
/// bitwise); `lru` rides the unbound-leaf fallback, which must also agree.
#[test]
fn shared_tournament_is_decision_exact_vs_peek_scan() {
    const SHARDS: usize = 3;
    const OPS: usize = 300;
    for h in [Heuristic::dtr_eq(), Heuristic::dtr(), Heuristic::lru()] {
        let run = |kind: GlobalIndexKind| {
            let pool = ServePool::new(400, ArbiterPolicy::GlobalReclaim, SHARDS)
                .with_global_index(kind);
            let stats = drive_fleet(&pool, SHARDS, OPS, h);
            pool.check_invariants().unwrap();
            assert_eq!(pool.used_bytes(), 0, "fleet teardown left bytes leased");
            stats
        };
        let scan = run(GlobalIndexKind::Scan);
        let shared = run(GlobalIndexKind::Shared);
        assert!(
            scan.iter().any(|s| s.evict_count > 0),
            "{}: fleet budget never bound; comparison is vacuous",
            h.name()
        );
        for (i, (a, b)) in scan.iter().zip(&shared).enumerate() {
            assert!(
                a.same_decisions(b),
                "{}: shard {i} diverged between scan and shared:\nscan   {:?}\nshared {:?}",
                h.name(),
                a,
                b
            );
        }
    }
}

#[test]
fn single_tenant_lstm_training_is_decision_exact() {
    const STEPS: usize = 4;
    let mk = |cfg: Config| LstmTrainer::interp(RnnConfig::tiny(), cfg).unwrap();
    let (peak, floor) = mk(Config::default()).measure_envelope(STEPS).unwrap();

    // Walk the rungs from loose to tight; the first rung the plain trainer
    // completes is the comparison point (tighter rungs may legitimately
    // OOM on the dynamic envelope).
    for pct in [70u64, 55] {
        let budget = headroom_budget(peak, floor, pct);
        let plain_cfg = Config {
            budget,
            heuristic: Heuristic::dtr_eq(),
            trace_victims: true,
            ..Config::default()
        };
        let mut plain = mk(plain_cfg);
        let mut expect: Vec<(f32, Stats)> = Vec::new();
        let mut ok = true;
        for _ in 0..STEPS {
            match plain.train_step() {
                Ok(r) => expect.push((r.loss, r.stats)),
                Err(_) => {
                    ok = false;
                    break;
                }
            }
        }
        if !ok {
            continue;
        }
        assert!(
            expect.iter().any(|(_, s)| s.evict_count > 0),
            "rung {pct}% never evicted; comparison is vacuous"
        );
        for policy in ArbiterPolicy::all() {
            let pool = ServePool::new(budget, policy, 1);
            let served_cfg = Config {
                heuristic: Heuristic::dtr_eq(),
                trace_victims: true,
                gate: Some(pool.lease()),
                ..Config::default()
            };
            let mut served = mk(served_cfg);
            for (i, (loss, stats)) in expect.iter().enumerate() {
                let r = served.train_step().unwrap_or_else(|e| {
                    panic!("served step {i} failed under {}: {e:#}", policy.name())
                });
                assert_eq!(*loss, r.loss, "loss diverged at step {i} ({})", policy.name());
                assert!(
                    stats.same_decisions(&r.stats),
                    "decisions diverged at step {i} under {}:\nplain  {:?}\nserved {:?}",
                    policy.name(),
                    stats,
                    r.stats
                );
            }
            pool.check_invariants().unwrap();
        }
        return;
    }
    panic!("no budget rung completed on the plain trainer");
}
