//! Content-addressed pinned-weight sharing (dedup) and cross-shard
//! request coalescing, under tenant churn. Pins the PR's acceptance
//! properties:
//!
//! * **Capacity**: N same-model tenants hold ~1/N of the private pinned
//!   bytes — the arbiter's shared ledger is charged once per distinct
//!   buffer, verified through `ServePool::shared_bytes` accounting.
//! * **Churn**: tenants joining and leaving mid-run bump and release
//!   refcounts; the ledger is refunded exactly once, when the *last*
//!   holder leaves, and a fully drained pool returns to zero bytes.
//! * **Exactness**: dedup on vs off is bit- and decision-exact at N=1
//!   (same losses, same victim sequences), and a coalesced infer batch
//!   returns bitwise the losses of serial member requests.
//!
//! CI runs this file in release mode as well (debug is too slow to stress
//! the multi-tenant interleavings hard).

use dtr::dtr::{Config, Heuristic};
use dtr::exec::{Engine, Optimizer};
use dtr::frontend::{run, FrontendConfig, Outcome, RequestOp};
use dtr::runtime::ModelConfig;
use dtr::serve::{
    fleet_budget, run_tenants, ArbiterPolicy, ServePool, TenantDriver, TenantKind, TenantSpec,
};

fn transformer_fleet(n: usize) -> Vec<TenantSpec> {
    (0..n).map(|i| TenantSpec { kind: TenantKind::Transformer, seed: 0x5EED + i as u64 }).collect()
}

fn driver_on(pool: &ServePool, heuristic: Heuristic) -> TenantDriver {
    let cfg = Config { heuristic, gate: Some(pool.lease()), ..Config::default() };
    TenantDriver::build_with_store(TenantKind::Transformer, cfg, 0, pool.store().cloned())
        .expect("tenant build")
}

/// N tenants of the same base model share ONE physical weight copy: the
/// shared ledger holds exactly one tenant's worth of parameter bytes no
/// matter how many tenants are live, while the private (dedup-off)
/// configuration pays it N times over.
#[test]
fn n_tenants_share_one_pinned_copy() {
    const N: usize = 4;
    let budget = 64 << 20;
    let pool = ServePool::new(budget, ArbiterPolicy::GlobalReclaim, N).with_dedup(true);
    let store = pool.store().expect("dedup pool has a store");

    let first = driver_on(&pool, Heuristic::dtr_eq());
    let one_copy = pool.shared_bytes();
    let distinct = store.distinct();
    assert!(one_copy > 0, "no pinned bytes were interned");
    assert!(distinct > 0);

    let mut rest: Vec<TenantDriver> = Vec::new();
    for _ in 1..N {
        rest.push(driver_on(&pool, Heuristic::dtr_eq()));
        // Every additional same-model tenant charges nothing: the pinned
        // floor is 1/N of what N private copies would cost.
        assert_eq!(pool.shared_bytes(), one_copy, "extra tenant was charged for shared weights");
        assert_eq!(store.distinct(), distinct, "identical buffers failed to dedup");
    }
    assert_eq!(store.total_refs(), N * distinct);
    // Quiescent (no sessions live): the only resident bytes ARE the single
    // shared copy — the arbiter-accounting form of the 1/N claim.
    assert_eq!(pool.used_bytes(), pool.shared_bytes());
    pool.check_invariants().unwrap();

    drop(first);
    drop(rest);
    assert_eq!(pool.shared_bytes(), 0);
    assert_eq!(pool.used_bytes(), 0);
    pool.check_invariants().unwrap();
}

/// Tenants joining and leaving mid-run (inference traffic in between):
/// refcounts track membership, the charge survives any proper subset of
/// holders leaving, and the refund lands exactly once — when the last
/// holder goes. Fine-tuning then un-shares: a tenant whose weights
/// diverge pays for its own copies, and still refunds them on exit.
#[test]
fn churn_refunds_exactly_once() {
    let pool = ServePool::new(64 << 20, ArbiterPolicy::StaticSplit, 4).with_dedup(true);

    let mut a = driver_on(&pool, Heuristic::dtr_eq());
    let one_copy = pool.shared_bytes();
    assert!(one_copy > 0);

    let mut b = driver_on(&pool, Heuristic::dtr_eq());
    a.infer().unwrap();
    b.infer().unwrap();
    assert_eq!(pool.shared_bytes(), one_copy);

    // Join mid-run...
    let mut c = driver_on(&pool, Heuristic::dtr_eq());
    assert_eq!(pool.shared_bytes(), one_copy);
    // ...leave mid-run: B's exit must NOT refund buffers A and C still hold.
    drop(b);
    assert_eq!(pool.shared_bytes(), one_copy, "refund fired before the last holder left");
    a.infer().unwrap();
    c.infer().unwrap();
    pool.check_invariants().unwrap();

    // A fine-tune step rewrites A's weights: its re-interned buffers no
    // longer match the base model, so the shared ledger grows past one
    // copy (A's divergent params) without disturbing C's.
    a.step().unwrap();
    assert!(pool.shared_bytes() > one_copy, "divergent weights cannot stay fully shared");
    c.infer().unwrap();

    drop(a);
    assert_eq!(pool.shared_bytes(), one_copy, "A's exit must refund exactly its own buffers");
    drop(c);
    assert_eq!(pool.shared_bytes(), 0);
    assert_eq!(pool.used_bytes(), 0);
    pool.check_invariants().unwrap();
}

/// Serving with dedup ON is bit- and decision-exact against dedup OFF at
/// N=1: same per-step losses, same victim sequences, same eviction
/// counts. Sharing moves pinned bytes to a different ledger — it must not
/// move a single eviction decision.
#[test]
fn single_tenant_dedup_is_decision_exact() {
    let mut sizing =
        Engine::interp(ModelConfig::tiny(), Config::default(), Optimizer::Sgd).expect("sizing");
    let budget = sizing.headroom_budget(70).expect("envelope");

    let run_steps = |dedup: bool| {
        let pool = ServePool::new(budget, ArbiterPolicy::GlobalReclaim, 1).with_dedup(dedup);
        let cfg = Config {
            heuristic: Heuristic::dtr_eq(),
            trace_victims: true,
            gate: Some(pool.lease()),
            ..Config::default()
        };
        let mut d =
            TenantDriver::build_with_store(TenantKind::Transformer, cfg, 0, pool.store().cloned())
                .expect("tenant build");
        let out: Vec<_> =
            (0..3).map(|_| d.step().map(|(l, s)| (l.to_bits(), s)).expect("step")).collect();
        drop(d);
        pool.check_invariants().unwrap();
        assert_eq!(pool.used_bytes(), 0);
        out
    };

    let on = run_steps(true);
    let off = run_steps(false);
    let evictions: u64 = off.iter().map(|(_, s)| s.evict_count).sum();
    assert!(evictions > 0, "budget never bound — the exactness claim is vacuous");
    for (i, ((lb_on, st_on), (lb_off, st_off))) in on.iter().zip(&off).enumerate() {
        assert_eq!(lb_on, lb_off, "step {i}: loss bits diverged between dedup on/off");
        assert!(
            st_on.same_decisions(st_off),
            "step {i}: eviction decisions diverged:\non  {st_on:?}\noff {st_off:?}"
        );
    }
}

/// A coalesced infer batch returns bitwise the losses serial service
/// produces: same engine config, same data stream, one stacked kernel
/// invocation vs n back-to-back singles.
#[test]
fn coalesced_infer_batch_matches_serial_bitwise() {
    const N: usize = 5;
    let mk = || Engine::interp(ModelConfig::tiny(), Config::default(), Optimizer::Sgd).unwrap();
    let mut serial = mk();
    let expect: Vec<u32> = (0..N).map(|_| serial.infer_step().unwrap().to_bits()).collect();
    let mut batched = mk();
    let got = batched.infer_batch(N).unwrap();
    assert_eq!(got.len(), N);
    for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
        assert_eq!(g.to_bits(), *e, "request {i}: coalesced loss diverged from serial");
    }
}

/// End-to-end: an all-transformer fleet trains concurrently over a dedup
/// pool; every tenant completes, and the drained pool refunds every byte
/// (threads joining and leaving ARE the churn here).
#[test]
fn dedup_fleet_trains_and_drains_clean() {
    let specs = transformer_fleet(4);
    let budget = fleet_budget(&specs, 80).expect("envelope");
    for policy in ArbiterPolicy::all() {
        let pool = ServePool::new(budget, policy, specs.len()).with_dedup(true);
        let base = Config { heuristic: Heuristic::dtr_eq(), ..Config::default() };
        let reports = run_tenants(&pool, &specs, &base, 3).expect("serve run");
        for r in &reports {
            assert!(r.error.is_none(), "tenant failed under {}: {:?}", policy.name(), r.error);
            assert_eq!(r.completed, 3);
        }
        assert_eq!(pool.shared_bytes(), 0, "drained pool still holds shared bytes");
        assert_eq!(pool.used_bytes(), 0);
        pool.check_invariants().unwrap();
    }
}

/// The front-end coalesces queued Infer runs into batched invocations
/// (events record the coalesced group size), completes every admitted
/// request, and produces the same outcome ledger with coalescing off.
#[test]
fn frontend_coalesces_infer_runs() {
    const REQS: usize = 24;
    let serve = |coalesce: bool| {
        let mut cfg = FrontendConfig::mixed(1);
        cfg.queue_cap = REQS;
        cfg.coalesce = coalesce;
        let budget = 64 << 20;
        let pool = ServePool::new(budget, ArbiterPolicy::GlobalReclaim, 1).with_dedup(true);
        let base = Config { heuristic: Heuristic::dtr_eq(), ..Config::default() };
        run(&pool, &cfg, &base, |h| {
            for _ in 0..REQS {
                assert!(h.submit(0, RequestOp::Infer), "queue under cap must admit");
            }
        })
        .expect("frontend run")
    };

    let on = serve(true);
    assert!(on.errors.is_empty(), "worker errors: {:?}", on.errors);
    let completed = on.events.iter().filter(|e| e.outcome == Outcome::Completed).count();
    assert_eq!(completed, REQS, "every admitted request must complete");
    // The client floods the queue before the worker can drain it, so the
    // worker must have served at least one multi-request coalesced group.
    assert!(
        on.events.iter().any(|e| e.batch >= 2 && e.outcome == Outcome::Completed),
        "no coalesced batch was recorded"
    );

    let off = serve(false);
    assert!(off.errors.is_empty());
    let off_completed = off.events.iter().filter(|e| e.outcome == Outcome::Completed).count();
    assert_eq!(off_completed, REQS, "coalescing must not change the outcome ledger");
}
