//! Index/scan equivalence properties for the `dtr::policy` subsystem:
//! every incremental victim-selection index must make *identical decisions*
//! to the reference `ScanIndex` — the same victim sequence and the same
//! decision-level `Stats` (`Stats::same_decisions`) — over random
//! training-shaped tapes, for the full Fig. 2 heuristic set plus
//! ablation-grid and Appendix-A heuristics. The indexes may only differ in
//! metadata-access counts (that is their point: Appendix E).
//!
//! Also pins that the Appendix E.2 search approximations (√n sampling +
//! small-tensor filter) compose with forced indexes without livelock, and
//! that the small filter alone preserves exact equivalence.

use dtr::dtr::{Config, CostKind, Heuristic, ParamSpec, PolicyKind};
use dtr::graphs::tape::{R, Tape};
use dtr::sim::log::Log;
use dtr::sim::replay::{baseline, simulate, SimOutcome};
use dtr::util::miniprop::check;
use dtr::util::rng::Rng;

/// Random layered training DAG via the Tape (fan-out, weights, releases).
fn random_model(rng: &mut Rng, size: usize) -> Log {
    let mut t = Tape::new("prop_policy");
    let x = t.data("x", 64 + rng.below(512));
    let mut frontier: Vec<R> = vec![x];
    let mut nodes = 0usize;
    while nodes < size {
        let k = 1 + rng.index(2.min(frontier.len()));
        let mut inputs: Vec<R> = (0..k).map(|_| *rng.choose(&frontier)).collect();
        if rng.chance(0.4) {
            let w = t.weight(&format!("w{nodes}"), 16 + rng.below(128));
            inputs.push(w);
        }
        let out = t.op(
            &format!("op{nodes}"),
            1 + rng.below(50),
            &inputs,
            32 + rng.below(1024),
        );
        frontier.push(out);
        if frontier.len() > 4 {
            frontier.remove(0);
        }
        nodes += 1;
    }
    let last = *frontier.last().unwrap();
    let loss = t.op("loss", 1, &[last], 8);
    t.finish(loss)
}

/// Clock-adversarial tape: interleaves bursts of accesses deep into the
/// history (epoch churn — re-freshened `last_access` plus remat storms when
/// the touched storages were evicted) with ordinary frontier progress, and
/// makes half the nodes share one `(cost, size)` cell so scores tie exactly
/// and only the lowest-id rule separates victims. Under a tight budget this
/// is the worst case for the differential index: mass evictions, eq-class
/// merges (dtr_eq cells), and constant tier migration.
fn adversarial_model(rng: &mut Rng, size: usize) -> Log {
    let mut t = Tape::new("prop_policy_adv");
    let x = t.data("x", 64 + rng.below(64));
    let mut all: Vec<R> = vec![x];
    let mut nodes = 0usize;
    while nodes < size {
        let mut inputs: Vec<R> = Vec::new();
        if rng.chance(0.35) && all.len() > 4 {
            // Access burst: touch storages from deep history.
            let k = 1 + rng.index(3);
            for _ in 0..k {
                inputs.push(*rng.choose(&all));
            }
        } else {
            let w = 4.min(all.len());
            inputs.push(all[all.len() - 1 - rng.index(w)]);
            if rng.chance(0.3) {
                inputs.push(*rng.choose(&all));
            }
        }
        // Half the nodes share one (cost, size) cell: exact score ties,
        // broken by lowest StorageId on both sides of the comparison.
        let (cost, bytes) = if rng.chance(0.5) {
            (2, 64)
        } else {
            (1 + rng.below(20), 32 + rng.below(256))
        };
        let out = t.op(&format!("op{nodes}"), cost, &inputs, bytes);
        all.push(out);
        nodes += 1;
    }
    let last = *all.last().unwrap();
    let loss = t.op("loss", 1, &[last], 8);
    t.finish(loss)
}

/// Heuristics under equivalence test: the Fig. 2 set, the Appendix-A
/// reduced heuristic, and staleness-/size-ablated grid cells that exercise
/// the lazy-heap index family.
fn equivalence_set() -> Vec<Heuristic> {
    let mut hs = Heuristic::fig2_set();
    hs.push(Heuristic::EStarCount);
    hs.push(Heuristic::Param(ParamSpec {
        cost: CostKind::EStar,
        use_size: true,
        use_staleness: false,
    }));
    hs.push(Heuristic::Param(ParamSpec {
        cost: CostKind::EqClass,
        use_size: false,
        use_staleness: false,
    }));
    hs.push(Heuristic::Param(ParamSpec {
        cost: CostKind::Local,
        use_size: false,
        use_staleness: true,
    }));
    hs
}

fn run(log: &Log, budget: u64, h: Heuristic, kind: PolicyKind, small_filter: bool) -> SimOutcome {
    simulate(
        log,
        Config {
            budget,
            heuristic: h,
            index: kind,
            small_filter,
            trace_victims: true,
            ..Config::default()
        },
    )
}

fn assert_equivalent(
    scan: &SimOutcome,
    indexed: &SimOutcome,
    h: Heuristic,
    what: &str,
) -> Result<(), String> {
    if scan.failed != indexed.failed {
        return Err(format!(
            "{} [{}]: feasibility diverged — scan {:?} vs indexed {:?}",
            h.name(),
            what,
            scan.failed,
            indexed.failed
        ));
    }
    if scan.stats.victims != indexed.stats.victims {
        let first = scan
            .stats
            .victims
            .iter()
            .zip(&indexed.stats.victims)
            .position(|(a, b)| a != b);
        return Err(format!(
            "{} [{}]: victim sequences diverged at {:?} (scan {} victims, indexed {})",
            h.name(),
            what,
            first,
            scan.stats.victims.len(),
            indexed.stats.victims.len()
        ));
    }
    if !scan.stats.same_decisions(&indexed.stats) {
        return Err(format!(
            "{} [{}]: victim sequences equal but decision stats diverged\n scan:    {:?}\n indexed: {:?}",
            h.name(),
            what,
            scan.stats,
            indexed.stats
        ));
    }
    Ok(())
}

/// The headline property: identical victim sequence and decision stats,
/// scan vs indexed, across the heuristic families and random budgets
/// (including infeasible ones — both sides must fail identically).
#[test]
fn prop_index_matches_scan_victim_sequences() {
    check("index_scan_equivalence", 40, 5, 35, |rng, size| {
        let log = random_model(rng, size);
        let b = baseline(&log);
        let budget = b.budget_at(0.2 + rng.f64() * 0.8);
        for h in equivalence_set() {
            let scan = run(&log, budget, h, PolicyKind::Scan, false);
            let indexed = run(&log, budget, h, PolicyKind::Indexed, false);
            assert_equivalent(&scan, &indexed, h, "plain")?;
        }
        Ok(())
    });
}

/// The small-tensor filter threshold is computed from the running pool-byte
/// counter and applied inside each index; equivalence must survive it.
#[test]
fn prop_small_filter_preserves_equivalence() {
    check("small_filter_equivalence", 30, 5, 30, |rng, size| {
        let log = random_model(rng, size);
        let b = baseline(&log);
        let budget = b.budget_at(0.3 + rng.f64() * 0.6);
        for h in [Heuristic::dtr(), Heuristic::dtr_eq(), Heuristic::lru(), Heuristic::size()] {
            let scan = run(&log, budget, h, PolicyKind::Scan, true);
            let indexed = run(&log, budget, h, PolicyKind::Indexed, true);
            assert_equivalent(&scan, &indexed, h, "small_filter")?;
            let diff = run(&log, budget, h, PolicyKind::Differential, true);
            assert_equivalent(&scan, &diff, h, "small_filter_differential")?;
        }
        Ok(())
    });
}

/// Clock-adversarial equivalence for the differential index (and the cached
/// scan it supersedes): long tapes interleaving access bursts (epoch
/// churn), mass evictions (tight budgets), and eq-class merges, across the
/// FULL ablation grid plus the Fig. 2 set. `PolicyKind::Differential`
/// forces the kinetic index onto every staleness-bearing cell — including
/// the `h_LRU` shape the staleness list normally takes — and victims plus
/// `Stats::same_decisions` must pin to the scan exactly, id-broken score
/// ties included.
#[test]
fn prop_clock_adversarial_differential_equivalence() {
    check("clock_adversarial_equivalence", 25, 15, 45, |rng, size| {
        let log = adversarial_model(rng, size);
        let b = baseline(&log);
        let budget = b.budget_at(0.2 + rng.f64() * 0.5);
        let mut hs = Heuristic::ablation_grid();
        hs.extend(Heuristic::fig2_set());
        for h in hs {
            let scan = run(&log, budget, h, PolicyKind::Scan, false);
            let diff = run(&log, budget, h, PolicyKind::Differential, false);
            assert_equivalent(&scan, &diff, h, "adversarial_differential")?;
            let cached = run(&log, budget, h, PolicyKind::Cached, false);
            assert_equivalent(&scan, &cached, h, "adversarial_cached")?;
        }
        Ok(())
    });
}

/// Deterministic tie gauntlet: a fan of identical `(cost, size)` siblings
/// repeatedly co-accessed (each merge op stamps both inputs with the same
/// completion clock, collapsing them into one epoch) so victim selection
/// degenerates to pure lowest-id tie-breaks inside shared tiers. The
/// differential index must reproduce the scan's choices eviction for
/// eviction — and evictions must actually occur for the pin to mean
/// anything.
#[test]
fn differential_breaks_score_ties_by_lowest_id() {
    let mut t = Tape::new("tie_fan");
    let x = t.data("x", 32);
    let mut sibs: Vec<R> = Vec::new();
    for i in 0..24usize {
        sibs.push(t.op(&format!("s{i}"), 3, &[x], 64));
    }
    let mut prev = sibs[0];
    for (i, &s) in sibs.iter().enumerate().skip(1) {
        prev = t.op(&format!("m{i}"), 3, &[prev, s], 64);
    }
    let loss = t.op("loss", 1, &[prev], 8);
    let log = t.finish(loss);
    let b = baseline(&log);
    let budget = b.budget_at(0.25);
    for h in [Heuristic::lru(), Heuristic::dtr(), Heuristic::dtr_eq(), Heuristic::dtr_local()] {
        let scan = run(&log, budget, h, PolicyKind::Scan, false);
        let diff = run(&log, budget, h, PolicyKind::Differential, false);
        assert_equivalent(&scan, &diff, h, "tie_fan").unwrap_or_else(|e| panic!("{e}"));
        assert!(
            !scan.stats.victims.is_empty(),
            "{}: tie fan produced no evictions — budget not tight enough",
            h.name()
        );
    }
}

/// √n sampling is a scan-coupled approximation: under `PolicyKind::Auto` it
/// routes to the scan (same RNG stream as the legacy inline path; victim
/// ties now resolve by lowest id), and under a forced index it is
/// superseded by the exact argmin — either way the run must terminate under
/// budget with invariants intact (no livelock when composed with the small
/// filter).
#[test]
fn prop_sampling_and_filter_compose_with_indexes() {
    check("sampling_filter_composition", 30, 8, 35, |rng, size| {
        let log = random_model(rng, size);
        let b = baseline(&log);
        let budget = b.budget_at(0.3 + rng.f64() * 0.6);
        let h = *rng.choose(&[
            Heuristic::dtr(),
            Heuristic::dtr_eq(),
            Heuristic::lru(),
            Heuristic::size(),
            Heuristic::Msps,
        ]);
        for kind in [PolicyKind::Auto, PolicyKind::Indexed] {
            let out = simulate(
                &log,
                Config {
                    budget,
                    heuristic: h,
                    index: kind,
                    sqrt_sample: true,
                    small_filter: true,
                    ..Config::default()
                },
            );
            if let Some(fail) = &out.failed {
                if !fail.contains("out of memory") {
                    return Err(format!("{} [{}]: {fail}", h.name(), kind.name()));
                }
                continue;
            }
            if out.stats.peak_memory > budget {
                return Err(format!(
                    "{} [{}]: peak {} exceeded budget {budget}",
                    h.name(),
                    kind.name(),
                    out.stats.peak_memory
                ));
            }
        }
        Ok(())
    });
}

/// Deterministic structured workload (a deep alias-free chain with releases
/// mid-stream) exercising banishment under both index kinds: decisions must
/// match even when the dealloc policy permanently removes storages.
#[test]
fn banish_policy_equivalence_on_chain() {
    use dtr::dtr::DeallocPolicy;
    let mut log = Log::new("banish_chain");
    log.constant("x", 8);
    let mut prev = "x".to_string();
    for i in 0..64usize {
        let out = format!("a{i}");
        log.call1(&format!("f{i}"), 1 + (i as u64 % 7), &[&prev], &out, 8 + (i as u64 % 5) * 4);
        if i >= 2 {
            // Keep a sliding window of two live activations.
            log.release(&format!("a{}", i - 2));
        }
        prev = out;
    }
    for h in [Heuristic::dtr(), Heuristic::dtr_eq(), Heuristic::lru()] {
        for policy in [DeallocPolicy::EagerEvict, DeallocPolicy::Banish, DeallocPolicy::Ignore] {
            let mk = |kind: PolicyKind| {
                simulate(
                    &log,
                    Config {
                        budget: 160,
                        heuristic: h,
                        policy,
                        index: kind,
                        trace_victims: true,
                        ..Config::default()
                    },
                )
            };
            let scan = mk(PolicyKind::Scan);
            let indexed = mk(PolicyKind::Indexed);
            assert_equivalent(&scan, &indexed, h, policy.name()).unwrap_or_else(|e| panic!("{e}"));
            let diff = mk(PolicyKind::Differential);
            assert_equivalent(&scan, &diff, h, policy.name()).unwrap_or_else(|e| panic!("{e}"));
            assert!(
                scan.ok(),
                "chain under {} / {} should be feasible at 160 bytes: {:?}",
                h.name(),
                policy.name(),
                scan.failed
            );
        }
    }
}
