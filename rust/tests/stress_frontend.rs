//! Front-end stress: bursty concurrent request streams through the
//! bounded-queue scheduler onto shard workers under one arbitrated
//! budget. Pins the three properties from the front-end's contract:
//!
//! * **Budget**: a live sampler never sees resident bytes above the
//!   global budget, under either arbiter policy.
//! * **No starvation**: every admitted request reaches a terminal
//!   outcome (submitted = completed + rejected + failed, with failed = 0
//!   under a feasible budget), and the completed-latency tail is bounded
//!   by the run itself (p99 <= wall clock — no request is left behind).
//! * **Backpressure**: sheds happen *only* against a full queue — every
//!   `Rejected` event records the queue depth it observed, and that depth
//!   is exactly the configured cap; under gentle load nothing is shed.
//!
//! After every run the drained pool's ledger must be balanced
//! (`check_invariants`) with zero bytes still leased.
//!
//! CI runs this file in release mode as well (debug is too slow to stress
//! thread interleavings hard).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use dtr::dtr::{Config, Heuristic};
use dtr::frontend::{frontend_budget, run, serve_bursty, FrontendConfig, Outcome, RequestOp};
use dtr::serve::{ArbiterPolicy, ServePool};

fn base() -> Config {
    Config { heuristic: Heuristic::dtr_eq(), ..Config::default() }
}

#[test]
fn bursty_streams_respect_budget_and_never_starve() {
    for policy in ArbiterPolicy::all() {
        let cfg = FrontendConfig::mixed(3);
        let budget = frontend_budget(&cfg.classes, 70).expect("envelope");
        let shards: usize = cfg.classes.iter().map(|c| c.shards).sum();
        let pool = ServePool::new(budget, policy, shards);

        // Live monitor: resident bytes across shards never exceed the
        // global budget at any sampled instant.
        let stop = Arc::new(AtomicBool::new(false));
        let sampler = {
            let stop = Arc::clone(&stop);
            let arb = Arc::clone(pool.arbiter());
            thread::spawn(move || {
                let mut max_used = 0u64;
                while !stop.load(Ordering::Acquire) {
                    max_used = max_used.max(arb.used_bytes());
                    thread::sleep(Duration::from_micros(200));
                }
                max_used
            })
        };

        let report = serve_bursty(&pool, &cfg, &base(), 10, 0xBEEF).expect("frontend run");

        stop.store(true, Ordering::Release);
        let max_used = sampler.join().expect("sampler thread");
        assert!(
            max_used <= budget,
            "{}: sampled {max_used} B resident > budget {budget} B",
            policy.name()
        );

        assert!(report.errors.is_empty(), "{}: {:?}", policy.name(), report.errors);
        let t = &report.total;
        assert_eq!(
            t.submitted,
            t.completed + t.rejected + t.failed,
            "{}: request accounting does not balance",
            policy.name()
        );
        assert_eq!(t.failed, 0, "{}: requests failed under a feasible budget", policy.name());
        assert_eq!(t.submitted, cfg.classes.len() * 10);
        for (ci, m) in report.classes.iter().enumerate() {
            assert!(m.completed > 0, "{}: class {ci} starved entirely", policy.name());
            assert_eq!(m.completed + m.rejected, m.submitted, "class {ci} lost requests");
        }
        // Bounded tail: the slowest completed request finished within the
        // run (its latency cannot exceed the wall clock), and the
        // percentile order is sane.
        assert!(t.p50_ns <= t.p95_ns && t.p95_ns <= t.p99_ns && t.p99_ns <= t.max_ns);
        assert!(
            t.max_ns <= report.wall_ns,
            "{}: a completed request outlived the run",
            policy.name()
        );

        assert_eq!(pool.used_bytes(), 0, "{}: drained run left bytes leased", policy.name());
        pool.check_invariants().expect("drained ledger balanced");
    }
}

/// Flood a cap-1 queue far faster than its single shard can serve: almost
/// everything must shed, and every shed must have happened against a full
/// queue (recorded depth == cap). The few admitted requests all complete.
#[test]
fn sheds_happen_only_against_a_full_queue() {
    let mut cfg = FrontendConfig::mixed(1); // one transformer class, one shard
    cfg.queue_cap = 1;
    let budget = frontend_budget(&cfg.classes, 100).expect("envelope");
    let pool = ServePool::new(budget, ArbiterPolicy::GlobalReclaim, 1);

    let report = run(&pool, &cfg, &base(), |h| {
        for _ in 0..400 {
            h.submit(0, RequestOp::FineTune);
        }
    })
    .expect("frontend run");

    let t = &report.total;
    assert_eq!(t.submitted, 400);
    assert_eq!(t.submitted, t.completed + t.rejected + t.failed);
    assert_eq!(t.failed, 0, "driver failed under an unconstrained budget");
    assert!(t.completed >= 1, "nothing was ever admitted");
    assert!(t.rejected > 0, "flood never overflowed the cap-1 queue");
    for ev in &report.events {
        if ev.outcome == Outcome::Rejected {
            assert_eq!(
                ev.queue_depth, cfg.queue_cap,
                "request {} shed against a non-full queue",
                ev.id
            );
        }
    }

    assert_eq!(pool.used_bytes(), 0);
    pool.check_invariants().expect("drained ledger balanced");
}

/// Gentle load far below the cap: nothing is shed, everything completes —
/// backpressure only engages at the cap, never earlier.
#[test]
fn gentle_load_is_never_shed() {
    let cfg = FrontendConfig::mixed(2); // default queue_cap 64
    let budget = frontend_budget(&cfg.classes, 100).expect("envelope");
    let shards: usize = cfg.classes.iter().map(|c| c.shards).sum();
    let pool = ServePool::new(budget, ArbiterPolicy::StaticSplit, shards);

    let report = run(&pool, &cfg, &base(), |h| {
        for i in 0..8 {
            for ci in 0..2 {
                assert!(h.submit(ci, if i % 2 == 0 { RequestOp::Infer } else { RequestOp::Probe }));
            }
            thread::sleep(Duration::from_millis(2));
        }
    })
    .expect("frontend run");

    let t = &report.total;
    assert_eq!(t.submitted, 16);
    assert_eq!(t.rejected, 0, "gentle load was shed below the cap");
    assert_eq!(t.completed, 16);
    assert_eq!(pool.used_bytes(), 0);
    pool.check_invariants().expect("drained ledger balanced");
}
