# Dynamic Tensor Rematerialization reproduction — top-level targets.
#
# `make verify` is the tier-1 gate (hermetic: no network, no Python, no
# artifacts needed — the engine runs on the pure-Rust interpreter backend).

.PHONY: verify build test bench bench-json bench-json-dtr bench-json-serve bench-json-quick fmt clippy e2e artifacts clean

# Tier-1 first (build + test), then the same gates CI runs: the pjrt
# feature-gate type-check (so the gated path cannot rot locally) and lints.
verify:
	cargo build --release && cargo test -q \
		&& cargo build --release --features pjrt \
		&& cargo fmt --check && cargo clippy -- -D warnings

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

# Machine-readable perf trajectory, committed as BENCH_*.json baselines in
# the repo root (CI also uploads fresh copies as workflow artifacts):
#  * BENCH_dtr.json   — bench_dtr kernel section (scalar vs row-kernel
#    GEMMs at the transformer shapes, threads 1/2/4) + eviction-scaling
#    (ns/eviction at growing pools, per heuristic: reference scan vs
#    cached-numerator scan vs the differential kinetic index, with a
#    100k/1M large-pool tier for the staleness-bearing h_dtr family);
#  * BENCH_serve.json — bench_serve multi-tenant scaling (aggregate
#    steps/sec + remat overhead vs tenant count, static-split vs
#    global-reclaim arbitration) + front-end requests/sec and p50/p99
#    latency vs tenant-class count (the `frontend` key).
# Both benches exit non-zero if a results array would be empty — for
# bench_serve that includes empty/zeroed front-end percentiles — (pass
# `--allow-empty` to override), so an empty trajectory file fails the make.
bench-json: bench-json-dtr bench-json-serve

bench-json-dtr:
	cargo bench --bench bench_dtr -- --json BENCH_dtr.json

bench-json-serve:
	cargo bench --bench bench_serve -- --json BENCH_serve.json

# CI-sized regeneration of the full trajectory (small pools, few iters,
# fewer tenants) — cheap enough to run on every push. Still includes the
# reduced 100k-pool differential-vs-cached eviction sweep (CI greps for
# those rows) so ns/eviction regressions are visible per push.
bench-json-quick:
	cargo bench --bench bench_dtr -- --json BENCH_dtr.json --quick
	cargo bench --bench bench_serve -- --json BENCH_serve.json --quick

fmt:
	cargo fmt --check

clippy:
	cargo clippy -- -D warnings

# Hermetic end-to-end training run (interpreter backend).
e2e:
	cargo run --release --example train_transformer -- --steps 100

# AOT-lower the JAX+Pallas ops to HLO artifacts for the optional PJRT
# backend (requires JAX; see python/compile/aot.py for dimension flags).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts

clean:
	cargo clean
	rm -rf results
