# Dynamic Tensor Rematerialization reproduction — top-level targets.
#
# `make verify` is the tier-1 gate (hermetic: no network, no Python, no
# artifacts needed — the engine runs on the pure-Rust interpreter backend).

.PHONY: verify build test bench bench-json fmt clippy e2e artifacts clean

# Tier-1 first (build + test), then the lint gates (same jobs CI runs).
verify:
	cargo build --release && cargo test -q && cargo fmt --check && cargo clippy -- -D warnings

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

# Machine-readable perf trajectory: the bench_dtr eviction-scaling section
# (ns/eviction at 1k/10k/100k pools, reference scan vs policy index) as
# BENCH_dtr.json in the repo root.
bench-json:
	cargo bench --bench bench_dtr -- --json BENCH_dtr.json

fmt:
	cargo fmt --check

clippy:
	cargo clippy -- -D warnings

# Hermetic end-to-end training run (interpreter backend).
e2e:
	cargo run --release --example train_transformer -- --steps 100

# AOT-lower the JAX+Pallas ops to HLO artifacts for the optional PJRT
# backend (requires JAX; see python/compile/aot.py for dimension flags).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts

clean:
	cargo clean
	rm -rf results
