# The kernel/model/AOT tests import JAX and hypothesis at module scope,
# which would error at collection time on machines without them (e.g. the
# hermetic rust CI). Ignore the test modules instead of erroring; the rust
# suite is the hermetic gate, these run where JAX (+Pallas) is installed.
import importlib.util

_MISSING = [m for m in ("jax", "hypothesis") if importlib.util.find_spec(m) is None]

collect_ignore_glob = ["test_*.py"] if _MISSING else []


def pytest_report_header(config):
    if _MISSING:
        return (
            "python/tests: ignored (missing "
            + ", ".join(_MISSING)
            + "); rust tests are hermetic — `cargo test -q`"
        )
    return None
