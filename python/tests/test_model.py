"""L2 correctness: the per-op transformer functions vs the kernel-free
reference model, gradient consistency, and optimizer-step semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    Config,
    adam_step,
    block_bwd,
    block_fwd,
    block_fwd_ref,
    embed_bwd,
    embed_fwd,
    init_params,
    loss_bwd,
    loss_fwd,
    model_loss_ref,
    model_loss_with_kernels,
    sgd_step,
)

CFG = Config(vocab=64, d_model=32, n_heads=2, d_ff=64, seq=16, batch=2, n_layers=2)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def batch():
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    tokens = jax.random.randint(k1, (CFG.batch, CFG.seq), 0, CFG.vocab)
    targets = jax.random.randint(k2, (CFG.batch, CFG.seq), 0, CFG.vocab)
    return tokens, targets


def blk_args(params, i):
    b = params["blocks"][i]
    return (b["ln1"], b["wqkv"], b["wo"], b["ln2"], b["w1"], b["w2"])


def test_block_fwd_matches_ref(params, batch):
    tokens, _ = batch
    x = embed_fwd(tokens, params["emb"])
    with_kernels = block_fwd(x, *blk_args(params, 0), n_heads=CFG.n_heads)
    ref = block_fwd_ref(x, *blk_args(params, 0), n_heads=CFG.n_heads)
    np.testing.assert_allclose(with_kernels, ref, rtol=1e-4, atol=1e-5)


def test_block_bwd_matches_ref_vjp(params, batch):
    tokens, _ = batch
    x = embed_fwd(tokens, params["emb"])
    dy = jax.random.normal(jax.random.PRNGKey(7), x.shape, jnp.float32)
    grads = block_bwd(x, *blk_args(params, 0), dy, n_heads=CFG.n_heads)
    _, pullback = jax.vjp(
        lambda *a: block_fwd_ref(*a, n_heads=CFG.n_heads), x, *blk_args(params, 0)
    )
    ref_grads = pullback(dy)
    assert len(grads) == 7
    for g, r in zip(grads, ref_grads):
        np.testing.assert_allclose(g, r, rtol=2e-3, atol=1e-4)


def test_full_model_kernels_vs_ref(params, batch):
    tokens, targets = batch
    a = model_loss_with_kernels(CFG, params, tokens, targets)
    b = model_loss_ref(CFG, params, tokens, targets)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_loss_is_sane_at_init(params, batch):
    tokens, targets = batch
    loss = model_loss_ref(CFG, params, tokens, targets)
    # Near-uniform logits at init: loss ≈ ln(vocab).
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5


def test_loss_bwd_matches_autodiff(params, batch):
    tokens, targets = batch
    x = embed_fwd(tokens, params["emb"])
    dx, dw = loss_bwd(x, params["w_out"], targets)
    gx, gw = jax.grad(
        lambda x_, w_: loss_fwd(x_, w_, targets)[0], argnums=(0, 1)
    )(x, params["w_out"])
    np.testing.assert_allclose(dx, gx, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(dw, gw, rtol=1e-4, atol=1e-6)


def test_embed_bwd_is_scatter_add(params, batch):
    tokens, _ = batch
    dy = jax.random.normal(jax.random.PRNGKey(3), (CFG.batch, CFG.seq, CFG.d_model))
    demb = embed_bwd(tokens, dy, vocab=CFG.vocab)
    ref = jax.grad(lambda e: jnp.vdot(embed_fwd(tokens, e), dy))(params["emb"])
    np.testing.assert_allclose(demb, ref, rtol=1e-5, atol=1e-6)


def test_gradient_descent_reduces_loss(params, batch):
    """A few SGD steps on the full model must reduce the loss — the core
    learning sanity check mirrored by the rust E2E driver."""
    tokens, targets = batch

    flat, tree = jax.tree_util.tree_flatten(params)

    def loss_of(flat_params):
        p = jax.tree_util.tree_unflatten(tree, flat_params)
        return model_loss_ref(CFG, p, tokens, targets)

    val0 = float(loss_of(flat))
    g = jax.grad(loss_of)(flat)
    flat2 = [p - 0.5 * gi for p, gi in zip(flat, g)]
    val1 = float(loss_of(flat2))
    assert val1 < val0, f"loss did not decrease: {val0} -> {val1}"


def test_adam_step_moves_towards_gradient():
    p = jnp.ones((4, 4))
    g = jnp.ones((4, 4))
    m = jnp.zeros((4, 4))
    v = jnp.zeros((4, 4))
    p2, m2, v2 = adam_step(p, g, m, v, jnp.ones(1))
    assert bool(jnp.all(p2 < p))
    assert bool(jnp.all(m2 > 0))
    assert bool(jnp.all(v2 > 0))


def test_adam_bias_correction_first_step():
    """At t=1 with fresh moments the update magnitude is ≈ lr."""
    p = jnp.zeros((8,))
    g = 3.0 * jnp.ones((8,))
    p2, _, _ = adam_step(p, g, jnp.zeros(8), jnp.zeros(8), jnp.ones(1), lr=1e-3)
    np.testing.assert_allclose(p2, -1e-3 * jnp.ones(8), rtol=1e-3)


def test_sgd_step():
    p = jnp.ones((4,))
    (p2,) = sgd_step(p, jnp.ones(4), lr=0.1)
    np.testing.assert_allclose(p2, 0.9 * jnp.ones(4), rtol=1e-6)


def test_config_param_count():
    assert CFG.total_params() == (
        CFG.vocab * CFG.d_model
        + CFG.n_layers * CFG.params_per_block()
        + CFG.d_model * CFG.vocab
    )
    assert Config().total_params() > 800_000
