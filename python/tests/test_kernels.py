"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py), with
hypothesis sweeping shapes and value distributions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import fused_attention, vmem_bytes
from compile.kernels.layernorm import fused_layernorm
from compile.kernels.ref import attention_ref, layernorm_ref, softmax_ref

SETTINGS = dict(max_examples=20, deadline=None)


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# --------------------------------------------------------------- attention


@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 2]),
    h=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([16, 32, 64]),
    dh=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_attention_matches_ref_causal(b, h, s, dh, seed):
    q, k, v = (rand(seed + i, (b, h, s, dh)) for i in range(3))
    out = fused_attention(q, k, v, causal=True, block_q=16, block_k=16)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(
    s=st.sampled_from([16, 32, 64]),
    blocks=st.sampled_from([(8, 8), (16, 16)]),
    seed=st.integers(0, 2**16),
)
def test_attention_matches_ref_noncausal(s, blocks, seed):
    bq, bk = blocks
    q, k, v = (rand(seed + i, (2, 2, s, 16)) for i in range(3))
    out = fused_attention(q, k, v, causal=False, block_q=bq, block_k=bk)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)


def test_attention_block_shape_invariance():
    """Different BlockSpec tilings must produce identical results."""
    q, k, v = (rand(i, (2, 2, 64, 16)) for i in range(3))
    a = fused_attention(q, k, v, block_q=16, block_k=16)
    b = fused_attention(q, k, v, block_q=32, block_k=32)
    c = fused_attention(q, k, v, block_q=64, block_k=64)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(b, c, rtol=1e-5, atol=1e-6)


def test_attention_causality():
    """Future tokens must not influence earlier positions."""
    q, k, v = (rand(i, (1, 1, 32, 16)) for i in range(3))
    out1 = fused_attention(q, k, v, causal=True, block_q=16, block_k=16)
    # Perturb the last key/value: positions < 31 must not change.
    k2 = k.at[:, :, -1].set(99.0)
    v2 = v.at[:, :, -1].set(-99.0)
    out2 = fused_attention(q, k2, v2, causal=True, block_q=16, block_k=16)
    np.testing.assert_allclose(out1[:, :, :-1], out2[:, :, :-1], rtol=1e-5, atol=1e-6)
    assert not np.allclose(out1[:, :, -1], out2[:, :, -1])


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16), scale=st.sampled_from([0.1, 1.0, 10.0]))
def test_attention_grad_matches_ref(seed, scale):
    q, k, v = (rand(seed + i, (1, 2, 32, 16), scale) for i in range(3))

    def f_kernel(q, k, v):
        return jnp.sum(fused_attention(q, k, v, block_q=16, block_k=16) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(attention_ref(q, k, v) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    # At scale 10 the softmax saturates to one-hot; tiny fwd differences
    # (1e-7) are amplified through the near-zero probabilities, so the
    # tolerance scales with the logit magnitude.
    tol = 2e-3 * max(1.0, scale)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=tol, atol=2e-4 * scale * scale)


def test_attention_extreme_values_stable():
    """Online softmax must not overflow on large logits."""
    q = 30.0 * jnp.ones((1, 1, 16, 8), jnp.float32)
    k = 30.0 * jnp.ones((1, 1, 16, 8), jnp.float32)
    v = rand(0, (1, 1, 16, 8))
    out = fused_attention(q, k, v, block_q=16, block_k=16)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_vmem_estimate_within_budget():
    # Default AOT config tile must fit a TPU core's ~16 MiB VMEM.
    assert vmem_bytes(32, 32, 512, 64) < 16 * 1024 * 1024


# --------------------------------------------------------------- layernorm


@settings(**SETTINGS)
@given(
    n=st.sampled_from([8, 32, 64]),
    d=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 2**16),
)
def test_layernorm_matches_ref(n, d, seed):
    x = rand(seed, (n, d), 3.0)
    g = rand(seed + 1, (d,))
    b = rand(seed + 2, (d,))
    out = fused_layernorm(x, g, b)
    ref = layernorm_ref(x, g, b)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16))
def test_layernorm_grads_match_ref(seed):
    x = rand(seed, (16, 32), 2.0)
    g = rand(seed + 1, (32,))
    b = rand(seed + 2, (32,))
    dy = rand(seed + 3, (16, 32))

    def with_kernel(x, g, b):
        return jnp.sum(fused_layernorm(x, g, b) * dy)

    def with_ref(x, g, b):
        return jnp.sum(layernorm_ref(x, g, b) * dy)

    gk = jax.grad(with_kernel, argnums=(0, 1, 2))(x, g, b)
    gr = jax.grad(with_ref, argnums=(0, 1, 2))(x, g, b)
    for a, r in zip(gk, gr):
        np.testing.assert_allclose(a, r, rtol=1e-3, atol=1e-4)


def test_layernorm_output_normalized():
    x = rand(0, (32, 64), 5.0)
    out = fused_layernorm(x, jnp.ones(64), jnp.zeros(64))
    np.testing.assert_allclose(np.mean(out, -1), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.std(out, -1), 1.0, atol=1e-2)


def test_softmax_ref_rows_sum_to_one():
    x = rand(1, (8, 16), 4.0)
    p = softmax_ref(x)
    np.testing.assert_allclose(np.sum(p, -1), 1.0, rtol=1e-6)


def test_layernorm_rejects_bad_blocking():
    x = rand(0, (10, 16))
    with pytest.raises(AssertionError):
        fused_layernorm(x, jnp.ones(16), jnp.zeros(16), block_rows=4)
