"""AOT pipeline checks: every op lowers to parseable HLO text with a
manifest that matches the declared shapes (the contract the rust runtime
relies on)."""

import json
import os

import pytest

from compile.aot import build_ops, compile_all, param_shapes, to_hlo_text
from compile.model import Config

TINY = Config(vocab=32, d_model=16, n_heads=2, d_ff=32, seq=8, batch=2, n_layers=1)


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = compile_all(TINY, str(out))
    return out, manifest


def test_all_ops_emitted(artifacts):
    out, manifest = artifacts
    expected = set(build_ops(TINY).keys())
    assert set(manifest["ops"].keys()) == expected
    for op in expected:
        path = out / f"{op}.hlo.txt"
        assert path.exists(), f"missing artifact {path}"
        text = path.read_text()
        assert text.startswith("HloModule"), f"{op} is not HLO text"
        assert "ROOT" in text


def test_manifest_roundtrips_json(artifacts):
    out, _ = artifacts
    with open(out / "manifest.json") as f:
        m = json.load(f)
    assert m["config"]["vocab"] == TINY.vocab
    assert m["total_params"] == TINY.total_params()
    assert set(m["param_shapes"]) == set(param_shapes(TINY))


def test_manifest_shapes_match_config(artifacts):
    _, m = artifacts
    b, s, d, v = TINY.batch, TINY.seq, TINY.d_model, TINY.vocab
    ef = m["ops"]["embed_fwd"]
    assert ef["inputs"][0] == {"shape": [b, s], "dtype": "i32"}
    assert ef["inputs"][1] == {"shape": [v, d], "dtype": "f32"}
    assert ef["outputs"][0] == {"shape": [b, s, d], "dtype": "f32"}
    bb = m["ops"]["block_bwd"]
    assert len(bb["inputs"]) == 8
    assert len(bb["outputs"]) == 7
    # dx mirrors x.
    assert bb["outputs"][0] == {"shape": [b, s, d], "dtype": "f32"}
    lf = m["ops"]["loss_fwd"]
    assert lf["outputs"][0]["shape"] == [1]


def test_adam_artifacts_cover_every_param_shape(artifacts):
    _, m = artifacts
    for name in param_shapes(TINY):
        assert f"adam_{name}" in m["ops"]
        assert f"sgd_{name}" in m["ops"]
        a = m["ops"][f"adam_{name}"]
        assert len(a["inputs"]) == 5
        assert len(a["outputs"]) == 3
        assert a["inputs"][0]["shape"] == param_shapes(TINY)[name]


def test_hlo_text_is_self_contained(artifacts):
    """No Mosaic/custom-call leakage: interpret-mode Pallas must lower to
    plain HLO the CPU PJRT client can run."""
    out, m = artifacts
    for op, meta in m["ops"].items():
        text = (out / meta["file"]).read_text()
        assert "mosaic" not in text.lower(), f"{op} contains a Mosaic custom call"


def test_lowering_deterministic():
    a = to_hlo_text(lambda x: x * 2.0, [__import__("jax").ShapeDtypeStruct((4,), "float32")])
    b = to_hlo_text(lambda x: x * 2.0, [__import__("jax").ShapeDtypeStruct((4,), "float32")])
    assert a == b
