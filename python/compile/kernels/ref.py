"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

Every kernel in this package must match its oracle to float32 tolerance
under pytest/hypothesis sweeps (python/tests/test_kernels.py). The oracles
are also used to build a reference (kernel-free) model for end-to-end
numerical checks of the L2 ops. They use only primitive jnp arithmetic so
they are maximally trustworthy as a spec.
"""

import jax.numpy as jnp


def softmax_ref(x, axis=-1):
    """Numerically stable softmax."""
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def layernorm_ref(x, gamma, beta, eps: float = 1e-5):
    """LayerNorm over the last axis. x: [..., D]; gamma/beta: [D]."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    y = (x - mean) / jnp.sqrt(var + eps)
    return y * gamma + beta


def attention_ref(q, k, v, causal: bool = True):
    """Scaled dot-product attention.

    q, k, v: [B, H, S, Dh] -> [B, H, S, Dh]. Causal masking by default
    (decoder LM). Softmax in float32.
    """
    dh = q.shape[-1]
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k) / jnp.sqrt(jnp.float32(dh))
    if causal:
        s = q.shape[2]
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(mask, scores, jnp.float32(-1e30))
    probs = softmax_ref(scores, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", probs, v)
