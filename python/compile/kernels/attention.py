"""Fused causal attention as a Pallas kernel (flash-attention structure).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's prototype
leans on cuDNN GPU kernels; our compute substrate is TPU-shaped Pallas. The
kernel streams K/V blocks HBM->VMEM with an online-softmax accumulator held
in VMEM scratch — the scratchpad analogue of the shared-memory tiling a CUDA
flash-attention uses — and shapes the contractions for the MXU (block sizes
multiples of the 128 lane width where the head dim allows).

Grid: one program per (batch*head, q_block). Each program loops over k/v
blocks up to the causal frontier, maintaining running max `m`, normalizer
`l`, and un-normalized accumulator `acc`.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO (loops + dots)
that runs on any backend. Real-TPU efficiency is estimated in
EXPERIMENTS.md §Perf from the VMEM footprint and MXU tile utilization.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, seq, causal):
    """One (batch*head, q_block) program: online softmax over k/v blocks."""
    qi = pl.program_id(1)
    q = q_ref[...]  # [block_q, dh]
    dh = q.shape[-1]
    scale = 1.0 / math.sqrt(dh)

    m = jnp.full((block_q, 1), NEG_INF, dtype=jnp.float32)
    l = jnp.zeros((block_q, 1), dtype=jnp.float32)
    acc = jnp.zeros((block_q, dh), dtype=jnp.float32)

    num_k_blocks = seq // block_k

    def body(kb, carry):
        m, l, acc = carry
        k = k_ref[pl.dslice(kb * block_k, block_k), :]
        v = v_ref[pl.dslice(kb * block_k, block_k), :]
        s = jnp.dot(q, k.T) * scale  # [block_q, block_k]
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            k_pos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.dot(p, v)
        return m_new, l_new, acc_new

    if causal:
        # Only k blocks at or before this q block contribute.
        last = qi + 1 if block_q == block_k else num_k_blocks
        m, l, acc = jax.lax.fori_loop(0, last, body, (m, l, acc))
    else:
        m, l, acc = jax.lax.fori_loop(0, num_k_blocks, body, (m, l, acc))

    o_ref[...] = (acc / l).astype(o_ref.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def fused_attention(q, k, v, causal: bool = True, block_q: int = 32, block_k: int = 32):
    """Pallas fused attention. q,k,v: [B, H, S, Dh] -> [B, H, S, Dh].

    S must be divisible by the block sizes (the AOT configs guarantee it;
    tests sweep shapes that satisfy it).

    Differentiable via custom_vjp: the backward pass replays the reference
    attention's vjp (flash-attention backward kernels recompute scores the
    same way; the XLA lowering fuses the recompute).
    """
    return _attention_impl(q, k, v, causal, block_q, block_k)


def _attention_impl(q, k, v, causal, block_q, block_k):
    b, h, s, dh = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, "seq must divide blocks"
    if causal:
        # The causal frontier arithmetic assumes square blocks.
        assert block_q == block_k, "causal path requires block_q == block_k"

    qf = q.reshape(b * h, s, dh)
    kf = k.reshape(b * h, s, dh)
    vf = v.reshape(b * h, s, dh)

    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k, seq=s, causal=causal
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, s // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, s, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, s, dh), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, dh), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dh), q.dtype),
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(b, h, s, dh)


def _attn_vjp_fwd(q, k, v, causal, block_q, block_k):
    return _attention_impl(q, k, v, causal, block_q, block_k), (q, k, v)


def _attn_vjp_bwd(causal, block_q, block_k, res, do):
    from .ref import attention_ref

    q, k, v = res
    _, pullback = jax.vjp(lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal), q, k, v)
    return pullback(do)


fused_attention.defvjp(_attn_vjp_fwd, _attn_vjp_bwd)


def vmem_bytes(block_q: int, block_k: int, seq: int, dh: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set per program (for the §Perf roofline note):
    q block + one k/v block pair + accumulators + the full-S k/v residency
    the BlockSpec requests."""
    q_blk = block_q * dh * dtype_bytes
    kv_stream = 2 * seq * dh * dtype_bytes  # spec'd per program
    acc = block_q * (dh + 2) * 4
    scores = block_q * block_k * 4
    return q_blk + kv_stream + acc + scores
