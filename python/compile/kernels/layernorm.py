"""Fused LayerNorm as a Pallas kernel.

One program per row block: mean/variance reduction and the scale+shift are
fused in VMEM, avoiding the three separate HBM round-trips (mean, var,
normalize) the unfused lowering takes. Rows map to the VPU sublane axis;
the feature dimension stays minor-most for lane-parallel reductions.

interpret=True for CPU-PJRT executability (see attention.py docstring).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)  # [block_rows, d]
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    y = (x - mean) / jnp.sqrt(var + eps)
    o_ref[...] = (y * g_ref[...] + b_ref[...]).astype(o_ref.dtype)


def _ln_bwd_kernel(x_ref, g_ref, dy_ref, dx_ref, dg_part_ref, db_part_ref, *, eps):
    """Per-row-block backward: dx in-kernel; per-block partial reductions for
    dgamma/dbeta (summed across blocks outside)."""
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...]
    dy = dy_ref[...].astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    rstd = 1.0 / jnp.sqrt(var + eps)
    xhat = (x - mean) * rstd
    dyg = dy * g
    m1 = jnp.mean(dyg, axis=-1, keepdims=True)
    m2 = jnp.mean(dyg * xhat, axis=-1, keepdims=True)
    dx_ref[...] = (rstd * (dyg - m1 - xhat * m2)).astype(dx_ref.dtype)
    dg_part_ref[...] = jnp.sum(dy * xhat, axis=0)
    db_part_ref[...] = jnp.sum(dy, axis=0)


def _ln_fwd_impl(x, gamma, beta, block_rows, eps):
    n, d = x.shape
    block_rows = min(block_rows, n)
    assert n % block_rows == 0, "rows must divide block_rows"
    kernel = functools.partial(_ln_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(x, gamma, beta)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def fused_layernorm(x, gamma, beta, block_rows: int = 8, eps: float = 1e-5):
    """x: [N, D] (callers flatten leading dims); gamma/beta: [D].

    Differentiable: both forward and backward run as Pallas kernels.
    """
    return _ln_fwd_impl(x, gamma, beta, block_rows, eps)


def _ln_vjp_fwd(x, gamma, beta, block_rows, eps):
    return _ln_fwd_impl(x, gamma, beta, block_rows, eps), (x, gamma)


def _ln_vjp_bwd(block_rows, eps, res, dy):
    x, gamma = res
    n, d = x.shape
    blocks = n // min(block_rows, n)
    br = n // blocks
    kernel = functools.partial(_ln_bwd_kernel, eps=eps)
    dx, dg_parts, db_parts = pl.pallas_call(
        kernel,
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((None, d), lambda i: (i, 0)),
            pl.BlockSpec((None, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), x.dtype),
            jax.ShapeDtypeStruct((blocks, d), jnp.float32),
            jax.ShapeDtypeStruct((blocks, d), jnp.float32),
        ],
        interpret=True,
    )(x, gamma, dy)
    return dx, jnp.sum(dg_parts, axis=0), jnp.sum(db_parts, axis=0)


fused_layernorm.defvjp(_ln_vjp_fwd, _ln_vjp_bwd)
