# L1: Pallas kernel(s) for the paper's compute hot-spot.
#
# These files double as the tiling specs for the Rust interpreter's kernel
# layer (rust/src/runtime/kernels/), which ports their discipline to the
# CPU hot path:
#
#   attention.py  (_attn_kernel)    -> kernels/fused.rs::causal_attention
#       online-softmax flash attention with running (m, l, acc) per query
#       row; the Rust port is the block_q = block_k = 1 degenerate form.
#   layernorm.py  (_ln_kernel,      -> kernels/fused.rs::layernorm,
#                  _ln_bwd_kernel)     kernels/fused.rs::layernorm_bwd
#       one pass per row, mean/var/rstd recomputed in-kernel.
#   ref.py        (scalar nests)    -> kernels/reference.rs
#       the unblocked loop nests, retained verbatim on the Rust side as
#       the bitwise equivalence oracle (rust/tests/prop_kernels.rs).
#
# The MXU-aligned accumulator blocking these specs assume maps to the
# rank-1 row kernel in kernels/gemm.rs (KU=8 unrolled updates per pass,
# k kept whole so every f32 accumulation chain matches the reference).
