"""L2: the transformer-LM compute graph in JAX, built on the Pallas kernels.

The model is factored into *per-op jitted functions* with self-contained
backward ops (`*_bwd` recomputes its internal intermediates via jax.vjp), so
the only cross-op state is the inter-op activation tensors — exactly the
granularity the DTR runtime checkpoints. Every op here is AOT-lowered once
by aot.py to an HLO-text artifact; Python never runs at training time.

Parameter layout per block (all f32):
    ln1  [2, D]     layernorm gamma;beta
    wqkv [D, 3D]    fused QKV projection
    wo   [D, D]     attention output projection
    ln2  [2, D]
    w1   [D, F]     MLP up
    w2   [F, D]     MLP down
Plus `emb [V, D]` (input embedding) and `w_out [D, V]` (untied LM head).
"""

from dataclasses import dataclass, asdict

import jax
import jax.numpy as jnp

from .kernels.attention import fused_attention
from .kernels.layernorm import fused_layernorm
from .kernels.ref import attention_ref, layernorm_ref, softmax_ref


@dataclass(frozen=True)
class Config:
    vocab: int = 512
    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 512
    seq: int = 64
    batch: int = 8
    n_layers: int = 4

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def params_per_block(self) -> int:
        d, f = self.d_model, self.d_ff
        return 2 * d + d * 3 * d + d * d + 2 * d + d * f + f * d

    def total_params(self) -> int:
        return (
            self.vocab * self.d_model
            + self.n_layers * self.params_per_block()
            + self.d_model * self.vocab
        )

    def to_dict(self):
        return asdict(self)


# --------------------------------------------------------------------- ops


def embed_fwd(tokens, emb):
    """tokens [B,S] i32, emb [V,D] -> x [B,S,D]."""
    return emb[tokens]


def embed_bwd(tokens, dy, vocab: int):
    """Gradient of embed_fwd w.r.t. emb: scatter-add of dy rows."""
    flat_tokens = tokens.reshape(-1)
    flat_dy = dy.reshape(-1, dy.shape[-1])
    demb = jnp.zeros((vocab, dy.shape[-1]), dtype=dy.dtype)
    return demb.at[flat_tokens].add(flat_dy)


def _block_fwd_impl(x, ln1, wqkv, wo, ln2, w1, w2, *, n_heads, use_kernels=True):
    b, s, d = x.shape
    dh = d // n_heads
    ln = _ln(use_kernels)
    # Attention sublayer (pre-norm).
    h = ln(x.reshape(b * s, d), ln1[0], ln1[1]).reshape(b, s, d)
    qkv = h @ wqkv  # [b, s, 3d]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):
        return t.reshape(b, s, n_heads, dh).transpose(0, 2, 1, 3)

    if use_kernels:
        attn = fused_attention(heads(q), heads(k), heads(v), causal=True)
    else:
        attn = attention_ref(heads(q), heads(k), heads(v), causal=True)
    attn = attn.transpose(0, 2, 1, 3).reshape(b, s, d)
    x = x + attn @ wo
    # MLP sublayer (pre-norm, GELU).
    h2 = ln(x.reshape(b * s, d), ln2[0], ln2[1]).reshape(b, s, d)
    ff = jax.nn.gelu(h2 @ w1, approximate=True) @ w2
    return x + ff


def _ln(use_kernels):
    if use_kernels:
        return fused_layernorm
    return layernorm_ref


def block_fwd(x, ln1, wqkv, wo, ln2, w1, w2, *, n_heads):
    return _block_fwd_impl(x, ln1, wqkv, wo, ln2, w1, w2, n_heads=n_heads)


def block_fwd_ref(x, ln1, wqkv, wo, ln2, w1, w2, *, n_heads):
    """Kernel-free oracle of block_fwd (pytest cross-check)."""
    return _block_fwd_impl(
        x, ln1, wqkv, wo, ln2, w1, w2, n_heads=n_heads, use_kernels=False
    )


def block_bwd(x, ln1, wqkv, wo, ln2, w1, w2, dy, *, n_heads):
    """Self-contained backward: recomputes block internals via vjp.

    Returns (dx, dln1, dwqkv, dwo, dln2, dw1, dw2).
    """
    # The vjp re-runs the forward inside this single jitted op, so the only
    # tensors DTR must keep (or rematerialize) across ops are x and dy.
    _, pullback = jax.vjp(
        lambda *args: block_fwd(*args, n_heads=n_heads), x, ln1, wqkv, wo, ln2, w1, w2
    )
    return pullback(dy)


def loss_fwd(x, w_out, targets):
    """Mean next-token cross-entropy. x [B,S,D], w_out [D,V], targets [B,S] i32.

    Returns a [1] tensor (scalar losses are awkward across the FFI).
    """
    logits = x @ w_out  # [B,S,V]
    logp = logits - jax.scipy.special.logsumexp(logits, axis=-1, keepdims=True)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll).reshape(1)


def loss_bwd(x, w_out, targets):
    """Returns (dx, dw_out) for unit upstream gradient."""
    _, pullback = jax.vjp(lambda x_, w_: loss_fwd(x_, w_, targets), x, w_out)
    return pullback(jnp.ones((1,), dtype=x.dtype))


def adam_step(p, g, m, v, t, *, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    """One Adam update; t is the 1-based step count as f32[1].

    Returns (p', m', v').
    """
    t = t[0]
    m2 = b1 * m + (1.0 - b1) * g
    v2 = b2 * v + (1.0 - b2) * g * g
    mhat = m2 / (1.0 - b1**t)
    vhat = v2 / (1.0 - b2**t)
    return p - lr * mhat / (jnp.sqrt(vhat) + eps), m2, v2


def sgd_step(p, g, *, lr=0.1):
    return (p - lr * g,)


# ------------------------------------------------- reference full model


def init_params(cfg: Config, key):
    """Reference initializer (pytest only; the rust coordinator initializes
    with the same scheme host-side)."""
    ks = jax.random.split(key, 2 + cfg.n_layers)
    scale = 0.02
    params = {
        "emb": scale * jax.random.normal(ks[0], (cfg.vocab, cfg.d_model), jnp.float32),
        "w_out": scale * jax.random.normal(ks[1], (cfg.d_model, cfg.vocab), jnp.float32),
        "blocks": [],
    }
    d, f = cfg.d_model, cfg.d_ff
    for i in range(cfg.n_layers):
        bk = jax.random.split(ks[2 + i], 4)
        ln = jnp.stack([jnp.ones(d), jnp.zeros(d)])
        params["blocks"].append(
            {
                "ln1": ln,
                "wqkv": scale * jax.random.normal(bk[0], (d, 3 * d), jnp.float32),
                "wo": scale * jax.random.normal(bk[1], (d, d), jnp.float32),
                "ln2": ln,
                "w1": scale * jax.random.normal(bk[2], (d, f), jnp.float32),
                "w2": scale * jax.random.normal(bk[3], (f, d), jnp.float32),
            }
        )
    return params


def model_loss_ref(cfg: Config, params, tokens, targets):
    """Whole-model loss using the kernel-free ops (numerical oracle)."""
    x = embed_fwd(tokens, params["emb"])
    for blk in params["blocks"]:
        x = block_fwd_ref(
            x, blk["ln1"], blk["wqkv"], blk["wo"], blk["ln2"], blk["w1"], blk["w2"],
            n_heads=cfg.n_heads,
        )
    return loss_fwd(x, params["w_out"], targets)[0]


def model_loss_with_kernels(cfg: Config, params, tokens, targets):
    """Whole-model loss chaining the AOT ops (pytest: matches the oracle)."""
    x = embed_fwd(tokens, params["emb"])
    for blk in params["blocks"]:
        x = block_fwd(
            x, blk["ln1"], blk["wqkv"], blk["wo"], blk["ln2"], blk["w1"], blk["w2"],
            n_heads=cfg.n_heads,
        )
    return loss_fwd(x, params["w_out"], targets)[0]
