"""AOT pipeline: lower every model op to an HLO-text artifact + manifest.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts \
            [--vocab 512 --d-model 128 --n-heads 4 --d-ff 512 \
             --seq 64 --batch 8 --n-layers 4]

Emits artifacts/<op>.hlo.txt for each op plus artifacts/manifest.json
describing shapes/dtypes so the rust runtime can build executables and
literals without any Python at run time.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import (
    Config,
    adam_step,
    block_bwd,
    block_fwd,
    embed_bwd,
    embed_fwd,
    loss_bwd,
    loss_fwd,
    sgd_step,
)


def to_hlo_text(fn, example_args) -> str:
    """Lower a python callable at fixed shapes to XLA HLO text."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype="f32"):
    jdt = {"f32": jnp.float32, "i32": jnp.int32}[dtype]
    return jax.ShapeDtypeStruct(tuple(shape), jdt)



def sig(s):
    dt = "f32" if s.dtype == jnp.float32 else "i32"
    return {"shape": [int(x) for x in s.shape], "dtype": dt}


def param_shapes(cfg: Config):
    """Name -> shape for every trainable parameter group."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    return {
        "emb": [v, d],
        "w_out": [d, v],
        "ln": [2, d],
        "wqkv": [d, 3 * d],
        "wo": [d, d],
        "w1": [d, f],
        "w2": [f, d],
    }


def build_ops(cfg: Config):
    """Return {op_name: (fn, [input specs], n_outputs)}."""
    b, s, d, v = cfg.batch, cfg.seq, cfg.d_model, cfg.vocab
    x = spec([b, s, d])
    tokens = spec([b, s], "i32")
    blk = [spec(sh) for sh in (
        [2, d], [d, 3 * d], [d, d], [2, d], [d, cfg.d_ff], [cfg.d_ff, d]
    )]
    ops = {
        "embed_fwd": (embed_fwd, [tokens, spec([v, d])], 1),
        "embed_bwd": (
            functools.partial(embed_bwd, vocab=v),
            [tokens, x],
            1,
        ),
        "block_fwd": (
            functools.partial(block_fwd, n_heads=cfg.n_heads),
            [x] + blk,
            1,
        ),
        "block_bwd": (
            functools.partial(block_bwd, n_heads=cfg.n_heads),
            [x] + blk + [x],
            7,
        ),
        "loss_fwd": (loss_fwd, [x, spec([d, v]), tokens], 1),
        "loss_bwd": (loss_bwd, [x, spec([d, v]), tokens], 2),
    }
    # Optimizer steps: one artifact per distinct parameter shape.
    for name, shape in param_shapes(cfg).items():
        p = spec(shape)
        ops[f"adam_{name}"] = (adam_step, [p, p, p, p, spec([1])], 3)
        ops[f"sgd_{name}"] = (sgd_step, [p, p], 1)
    return ops


def compile_all(cfg: Config, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    ops = build_ops(cfg)
    manifest = {
        "config": cfg.to_dict(),
        "total_params": cfg.total_params(),
        "param_shapes": param_shapes(cfg),
        "ops": {},
    }
    for name, (fn, args, n_out) in ops.items():
        text = to_hlo_text(fn, args)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        # Output signatures come from the jit eval shape.
        out_shapes = jax.eval_shape(fn, *args)
        if not isinstance(out_shapes, (tuple, list)):
            out_shapes = (out_shapes,)
        assert len(out_shapes) == n_out, f"{name}: {len(out_shapes)} != {n_out}"
        manifest["ops"][name] = {
            "file": fname,
            "inputs": [sig(a) for a in args],
            "outputs": [sig(o) for o in out_shapes],
        }
        print(f"  lowered {name}: {len(text)} chars")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--n-heads", type=int, default=4)
    ap.add_argument("--d-ff", type=int, default=512)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--n-layers", type=int, default=4)
    a = ap.parse_args()
    cfg = Config(
        vocab=a.vocab,
        d_model=a.d_model,
        n_heads=a.n_heads,
        d_ff=a.d_ff,
        seq=a.seq,
        batch=a.batch,
        n_layers=a.n_layers,
    )
    print(f"AOT-compiling {cfg} ({cfg.total_params():,} params) -> {a.out_dir}")
    compile_all(cfg, a.out_dir)
    print("done")


if __name__ == "__main__":
    main()
