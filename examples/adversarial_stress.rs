//! Adversarial stress example: Theorem 3.2 live.
//!
//! An adaptive adversary builds the network one node at a time, always
//! extending a fully evicted path, and forces every deterministic heuristic
//! into Ω(N/B) overhead — while the optimal static plan (which may reorder)
//! stays at Θ(N). Prints the measured ratio next to N/B for each heuristic.
//!
//!     cargo run --release --example adversarial_stress -- [--n 512] [--b 8]

use dtr::dtr::Heuristic;
use dtr::graphs::adversarial::run_adversary;
use dtr::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.usize_or("n", 512);
    let b = args.usize_or("b", 8);
    println!("adversary: n={n}, budget={b}  (theory: ratio = Ω(N/B) = Ω({}))\n", n / b);
    println!("{:<16} {:>10} {:>10} {:>8}", "heuristic", "dtr_ops", "static", "ratio");
    for h in [
        Heuristic::dtr(),
        Heuristic::dtr_eq(),
        Heuristic::dtr_local(),
        Heuristic::lru(),
        Heuristic::size(),
        Heuristic::Msps,
        Heuristic::Random,
    ] {
        let r = run_adversary(n, b, h)?;
        println!(
            "{:<16} {:>10} {:>10} {:>8.1}x",
            h.name(),
            r.dtr_ops,
            r.static_ops,
            r.ratio()
        );
    }
    println!(
        "\nEvery deterministic heuristic pays the lower bound; randomization \
         does not escape it either\n(the adversary here is adaptive)."
    );
    Ok(())
}
