//! Dynamic-model example: TreeLSTM over *randomly shaped* trees — the
//! workload class static checkpointing cannot plan for (every input has a
//! different computation graph) and DTR handles natively (Sec. 1, Table 1).
//!
//! For each randomly generated tree we build the operation stream on the
//! fly against the DTR runtime, under a fixed memory budget sized for the
//! *average* tree. Large trees only fit thanks to rematerialization.
//!
//!     cargo run --release --example dynamic_treelstm

use dtr::dtr::{Config, Heuristic, NullBackend, OutSpec, Runtime, TensorId};
use dtr::util::rng::Rng;

const HIDDEN_BYTES: u64 = 64 * 64 * 4; // batch 64, hidden 64, f32
const COMBINE_COST: u64 = 4;

/// Recursively evaluate a random binary tree through the runtime, returning
/// the root representation tensor. `budget_stress` makes every combine emit
/// three ops (gate-left, gate-right, combine) like a real TreeLSTM cell.
fn eval_tree(
    rt: &mut Runtime<NullBackend>,
    rng: &mut Rng,
    depth: usize,
    leaf_w: TensorId,
    comb_w: TensorId,
    acts: &mut Vec<TensorId>,
) -> anyhow::Result<TensorId> {
    // Random topology: probability of splitting decays with depth.
    if depth > 0 && rng.chance(0.85) {
        let l = eval_tree(rt, rng, depth - 1, leaf_w, comb_w, acts)?;
        let r = eval_tree(rt, rng, depth - 1, leaf_w, comb_w, acts)?;
        let gl = rt.call("gate_l", COMBINE_COST, &[l, comb_w], &[OutSpec::sized(HIDDEN_BYTES)])?[0];
        let gr = rt.call("gate_r", COMBINE_COST, &[r, comb_w], &[OutSpec::sized(HIDDEN_BYTES)])?[0];
        let c = rt.call("combine", COMBINE_COST, &[gl, gr], &[OutSpec::sized(HIDDEN_BYTES)])?[0];
        // Gates die once combined; node outputs stay referenced for the
        // backward sweep (training keeps activations live — or DTR evicts
        // and rematerializes them).
        for t in [gl, gr] {
            rt.release(t);
        }
        acts.push(c);
        Ok(c)
    } else {
        // Leaf: embed a token (the shared weight stands in for the token
        // batch; a per-leaf pinned constant would accumulate memory).
        let e = rt.call("embed", 2, &[leaf_w], &[OutSpec::sized(HIDDEN_BYTES)])?[0];
        acts.push(e);
        Ok(e)
    }
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(0xF0);

    for trial in 0..8 {
        let depth = 8 + rng.index(5); // depth 8..=12: wildly varying graphs
        // Budget scaled to the *depth* only (the tree's true size is
        // unknown in advance — that's the point of a dynamic model): deep
        // rematerialization paths need ~2·depth live tensors, so this is
        // enough to run but far below the tree's full footprint.
        let budget = (4 * depth as u64 + 16) * HIDDEN_BYTES;
        let cfg = Config { budget, heuristic: Heuristic::dtr_eq(), ..Config::default() };
        let mut rt: Runtime<NullBackend> = Runtime::new(cfg, NullBackend::new());
        let leaf_w = rt.constant(64 * 64 * 4);
        let comb_w = rt.constant(64 * 64 * 4);
        let mut acts = Vec::new();
        let result = eval_tree(&mut rt, &mut rng, depth, leaf_w, comb_w, &mut acts)
            .and_then(|root| {
                // Backward sweep: gradients need every forward activation in
                // reverse order; evicted ones are rematerialized on demand.
                let mut grad = root;
                for &a in acts.iter().rev() {
                    let g = rt.call("bwd", COMBINE_COST, &[a, grad], &[OutSpec::sized(HIDDEN_BYTES)])?[0];
                    if grad != root {
                        rt.release(grad);
                    }
                    rt.release(a);
                    grad = g;
                }
                Ok(())
            });
        match result {
            Ok(()) => {
                rt.check_invariants()?;
                let s = &rt.stats;
                println!(
                    "tree {trial}: depth<={depth} budget={:>4.1}MiB nodes={} peak={:.1} MiB evictions={} remats={} slowdown={:.2}x",
                    budget as f64 / (1 << 20) as f64,
                    acts.len() as u64,
                    s.peak_memory as f64 / (1 << 20) as f64,
                    s.evict_count,
                    s.remat_count,
                    s.slowdown(),
                );
            }
            Err(e) => {
                // The paper's Sec. 2: below a model-dependent threshold,
                // rematerialization can fail — report it like Table 1's "X".
                println!("tree {trial}: depth<={depth} OOM ({e})");
            }
        }
    }
    println!("ok: dynamic graphs handled with zero ahead-of-time planning");
    Ok(())
}
