//! Dynamic-model example: *really training* a TreeLSTM over randomly
//! shaped trees on the hermetic interpreter — the workload class static
//! checkpointing cannot plan for (every batch has a different computation
//! graph) and DTR handles natively (Sec. 1, Table 1).
//!
//! The budget is fixed *before* any tree shape is known, from a short
//! unbudgeted dry run; each step then builds its op stream on the fly
//! against a `dtr::api::Session`. Large trees only fit thanks to
//! rematerialization, and because replay is exact, the loss trajectory is
//! bitwise identical to the unbudgeted run.
//!
//!     cargo run --release --example dynamic_treelstm [--steps 40] [--pct 45]

use dtr::dtr::{Config, Heuristic};
use dtr::exec::dynamic::{headroom_budget, TreeLstmTrainer};
use dtr::runtime::RnnConfig;
use dtr::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 40);
    let pct = args.u64_or("pct", 45);

    let rnn = RnnConfig::tiny();
    // Size the budget from the dynamic envelope: a dry run over the step
    // stream measures the pinned floor and the unbudgeted peak; we then
    // keep only `pct`% of the headroom between them.
    let (peak, floor) = TreeLstmTrainer::interp(rnn, Config::default())?.measure_envelope(8)?;
    let budget = headroom_budget(peak, floor, pct);
    println!(
        "dynamic envelope: floor {:.1} KiB, peak {:.1} KiB -> budget {:.1} KiB ({pct}% headroom)\n",
        floor as f64 / 1024.0,
        peak as f64 / 1024.0,
        budget as f64 / 1024.0,
    );

    let cfg = Config { budget, heuristic: Heuristic::dtr_eq(), ..Config::default() };
    let mut trainer = TreeLstmTrainer::interp(rnn, cfg)?;
    let before = trainer.probe_loss(99)?;

    let mut remats = 0u64;
    let mut evictions = 0u64;
    for step in 1..=steps {
        match trainer.train_step() {
            Ok(r) => {
                remats += r.stats.remat_count;
                evictions += r.stats.evict_count;
                if step % 10 == 0 || step == 1 {
                    println!(
                        "step {step:>3}  leaves {:>2}  loss {:.4}  peak {:>6.1} KiB  evict {:>3}  remat {:>3}",
                        r.units,
                        r.loss,
                        r.stats.peak_memory as f64 / 1024.0,
                        r.stats.evict_count,
                        r.stats.remat_count,
                    );
                }
            }
            Err(e) => {
                // Below a model-dependent threshold rematerialization can
                // fail (Table 1's "X") — but then this run verified
                // nothing, so exit nonzero rather than masquerading as a
                // pass (raise --pct to restore headroom).
                anyhow::bail!("step {step}: OOM under budget {budget}: {e}");
            }
        }
    }

    let after = trainer.probe_loss(99)?;
    anyhow::ensure!(after < before, "probe loss did not descend: {before} -> {after}");
    println!(
        "\nok: probe loss {before:.4} -> {after:.4} | {evictions} evictions, {remats} remats | \
         zero ahead-of-time planning"
    );
    Ok(())
}
