//! Quickstart: the DTR public API in five minutes.
//!
//! Builds a small computation through a `dtr::api::Session` under a tight
//! memory budget, watches DTR evict and rematerialize behind RAII tensor
//! handles, and prints the stats. No raw tensor ids, no manual releases:
//! dropping a handle *is* the deallocation event.
//!
//!     cargo run --release --example quickstart

use dtr::api::{Session, Tensor};
use dtr::dtr::{Config, Heuristic};

fn main() -> anyhow::Result<()> {
    // An accounting session with a 6-unit memory budget using the paper's
    // h_DTR^eq heuristic (the prototype default). Accounting sessions track
    // sizes and costs only — perfect for exploring DTR's decisions.
    let cfg = Config { budget: 6, heuristic: Heuristic::dtr_eq(), ..Config::default() };
    let s = Session::accounting(cfg);

    // A constant input (weights/data are pinned: never evicted).
    let x0 = s.constant_sized(1);

    // A chain of 32 unit-cost, unit-size operators. With only 6 units of
    // memory, DTR must evict intermediate tensors as it goes.
    let mut xs: Vec<Tensor> = vec![x0];
    for i in 0..32 {
        let t = s.call_sized(&format!("f{i}"), /*cost=*/ 1, &[&xs[i]], &[1])?.remove(0);
        xs.push(t);
    }
    let stats = s.stats();
    println!("after forward: {} evictions, memory = {}/6", stats.evict_count, stats.memory);

    // Touch an early tensor: it was evicted, so DTR transparently replays
    // its parent operators (recursively) to bring it back.
    assert!(!s.is_defined(&xs[4]));
    s.touch(&xs[4])?;
    assert!(s.is_defined(&xs[4]));
    let stats = s.stats();
    println!(
        "after touch(t4): {} rematerializations ({} extra compute units)",
        stats.remat_count, stats.remat_compute
    );

    // Deallocation is just Drop: truncating the vector releases the handles
    // and the eager policy frees their storage immediately (Sec. 2
    // "Deallocation"). Cloning a handle would retain it instead.
    drop(xs.drain(1..16));
    println!("after drops: memory = {}", s.memory());

    // Every heuristic from the paper is available, and each name parses
    // back with FromStr (the CLI/CSV contract).
    for h in Heuristic::fig2_set() {
        let parsed: Heuristic = h.name().parse().unwrap();
        assert_eq!(parsed, h);
        println!("heuristic available: {}", h.name());
    }

    s.check_invariants()?;
    println!("ok: slowdown = {:.2}x", s.stats().slowdown());
    Ok(())
}
