//! Quickstart: the DTR public API in five minutes.
//!
//! Builds a small computation through the runtime under a tight memory
//! budget, watches DTR evict and rematerialize, and prints the stats.
//!
//!     cargo run --release --example quickstart

use dtr::dtr::{Config, Heuristic, NullBackend, OutSpec, Runtime};

fn main() -> anyhow::Result<()> {
    // A runtime with a 6-unit memory budget using the paper's h_DTR^eq
    // heuristic (the prototype default).
    let cfg = Config { budget: 6, heuristic: Heuristic::dtr_eq(), ..Config::default() };
    let mut rt: Runtime<NullBackend> = Runtime::new(cfg, NullBackend::new());

    // A constant input (weights/data are pinned: never evicted).
    let x0 = rt.constant(1);

    // A chain of 32 unit-cost, unit-size operators. With only 6 units of
    // memory, DTR must evict intermediate tensors as it goes.
    let mut xs = vec![x0];
    for i in 0..32 {
        let t = rt.call(&format!("f{i}"), /*cost=*/ 1, &[xs[i]], &[OutSpec::sized(1)])?[0];
        xs.push(t);
    }
    println!("after forward: {} evictions, memory = {}/6", rt.stats.evict_count, rt.stats.memory);

    // Touch an early tensor: it was evicted, so DTR transparently replays
    // its parent operators (recursively) to bring it back.
    assert!(!rt.is_defined(xs[4]));
    rt.access(xs[4])?;
    assert!(rt.is_defined(xs[4]));
    println!(
        "after access(t4): {} rematerializations ({} extra compute units)",
        rt.stats.remat_count, rt.stats.remat_compute
    );

    // Deallocation: dropping the last reference lets the eager policy free
    // tensors immediately (Sec. 2 "Deallocation").
    for &t in &xs[1..16] {
        rt.release(t);
    }
    println!("after releases: memory = {}", rt.stats.memory);

    // Every heuristic from the paper is available:
    for h in Heuristic::fig2_set() {
        println!("heuristic available: {}", h.name());
    }

    rt.check_invariants()?;
    println!("ok: slowdown = {:.2}x", rt.stats.slowdown());
    Ok(())
}
