//! End-to-end driver: train the transformer LM through the full three-layer
//! stack — rust coordinator -> DTR runtime -> PJRT executables compiled from
//! JAX+Pallas — under a restricted memory budget, and log the loss curve.
//!
//! Requires artifacts: `make artifacts` (or `make e2e` which runs this).
//!
//!     cargo run --release --example train_transformer -- \
//!         [--steps 200] [--budget-ratio 0.5] [--heuristic h_dtr_eq] \
//!         [--curve-out results/e2e_loss.csv]
//!
//! The run demonstrates all layers composing: Pallas fused attention +
//! layernorm kernels inside the JAX block ops, AOT-lowered to HLO, executed
//! by the rust engine with DTR evicting/rematerializing real activation
//! buffers. Under any budget the loss trajectory is bitwise identical to
//! the unbudgeted run (rematerialization is exact replay).

use dtr::coordinator::{train, TrainConfig};
use dtr::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut cfg = TrainConfig::load(&args)?;
    if args.get("steps").is_none() {
        cfg.steps = 200;
    }
    if cfg.curve_out.is_none() {
        cfg.curve_out = Some("results/e2e_loss.csv".into());
    }
    println!(
        "training with budget_ratio={:?} heuristic={} for {} steps",
        cfg.budget_ratio,
        cfg.heuristic.name(),
        cfg.steps
    );
    let report = train(&cfg)?;

    // The loss curve must descend: the model is learning a deterministic
    // token remap through the full AOT stack.
    let first = report.losses.first().copied().unwrap();
    let last = report.losses.last().copied().unwrap();
    anyhow::ensure!(last < first, "loss did not descend: {first} -> {last}");
    println!(
        "\nE2E OK: {} params | loss {:.4} -> {:.4} | {:.0} tok/s | \
         peak {:.1} MiB (budget {:.1} MiB) | {} remats total",
        report.total_params,
        first,
        last,
        report.tokens_per_sec(),
        report.peak_budgeted as f64 / (1 << 20) as f64,
        report.budget as f64 / (1 << 20) as f64,
        report.total_remats,
    );
    Ok(())
}
