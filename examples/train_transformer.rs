//! End-to-end driver: train the transformer LM through the full stack —
//! rust coordinator -> DTR runtime -> pluggable executor — under a
//! restricted memory budget, and log the loss curve.
//!
//! Hermetic by default (pure-Rust interpreter backend, no artifacts):
//!
//!     cargo run --release --example train_transformer -- \
//!         [--steps 200] [--budget-ratio 0.8] [--heuristic h_dtr_eq] \
//!         [--curve-out results/e2e_loss.csv] \
//!         [--vocab 256 --d-model 64 --layers 2 ...]
//!
//! `--budget-ratio` is a fraction of the non-pinned headroom above the
//! pinned-constant floor (params + optimizer state); the feasibility floor
//! sits near 0.6 (the block_bwd working set), so 0.7–0.9 are the
//! interesting budgets.
//!
//! With `--features pjrt` and compiled artifacts, `--backend pjrt` runs the
//! same training through PJRT executables AOT-lowered from the JAX+Pallas
//! ops instead. Under any budget the loss trajectory is bitwise identical
//! to the unbudgeted run (rematerialization is exact replay).

use dtr::coordinator::{train, TrainConfig};
use dtr::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut cfg = TrainConfig::load(&args)?;
    if args.get("steps").is_none() {
        cfg.steps = 200;
    }
    if cfg.curve_out.is_none() {
        cfg.curve_out = Some("results/e2e_loss.csv".into());
    }
    println!(
        "training with budget_ratio={:?} heuristic={} for {} steps",
        cfg.budget_ratio,
        cfg.heuristic.name(),
        cfg.steps
    );
    let report = train(&cfg)?;

    // The loss curve must descend: the model is learning a deterministic
    // token remap through the full AOT stack.
    let first = report.losses.first().copied().unwrap();
    let last = report.losses.last().copied().unwrap();
    anyhow::ensure!(last < first, "loss did not descend: {first} -> {last}");
    println!(
        "\nE2E OK: {} params | loss {:.4} -> {:.4} | {:.0} tok/s | \
         peak {:.1} MiB (budget {:.1} MiB) | {} remats total",
        report.total_params,
        first,
        last,
        report.tokens_per_sec(),
        report.peak_budgeted as f64 / (1 << 20) as f64,
        report.budget as f64 / (1 << 20) as f64,
        report.total_remats,
    );
    Ok(())
}
